"""Make `repro` (src layout) and `benchmarks` importable for test runs that
haven't `pip install -e .`'d the package (e.g. bare `python -m pytest`)."""
import sys
from pathlib import Path

_SRC = str(Path(__file__).resolve().parent / "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)
