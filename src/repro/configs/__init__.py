"""Config registry: get_config(arch_id, smoke=False) for the 10 assigned
architectures (plus shape-cell definitions shared by dryrun/benchmarks)."""
from __future__ import annotations

import dataclasses
import importlib

from repro.configs.base import ModelConfig

_MODULES = {
    "qwen3-1.7b": "qwen3_1p7b",
    "gemma3-27b": "gemma3_27b",
    "minicpm-2b": "minicpm_2b",
    "internlm2-1.8b": "internlm2_1p8b",
    "rwkv6-7b": "rwkv6_7b",
    "arctic-480b": "arctic_480b",
    "phi3.5-moe-42b-a6.6b": "phi35_moe",
    "hymba-1.5b": "hymba_1p5b",
    "whisper-base": "whisper_base",
    "qwen2-vl-2b": "qwen2_vl_2b",
}

ARCH_IDS = tuple(_MODULES)


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"


SHAPES = (
    ShapeCell("train_4k", 4096, 256, "train"),
    ShapeCell("prefill_32k", 32768, 32, "prefill"),
    ShapeCell("decode_32k", 32768, 128, "decode"),
    ShapeCell("long_500k", 524288, 1, "decode"),
)

# long_500k needs sub-quadratic attention / bounded state; skip for pure
# full-attention archs (see DESIGN.md "Shape-cell skips").
LONG_CONTEXT_ARCHS = ("rwkv6-7b", "hymba-1.5b", "gemma3-27b")


def cell_is_applicable(arch: str, shape: ShapeCell) -> tuple[bool, str]:
    if shape.name == "long_500k" and arch not in LONG_CONTEXT_ARCHS:
        return False, "pure full-attention arch: 512k decode skipped"
    return True, ""


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {list(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.SMOKE if smoke else mod.FULL
