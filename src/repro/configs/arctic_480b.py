"""arctic-480b [moe]: 35L d=7168 56H (GQA kv=8) d_ff=4864 vocab=32000,
MoE 128 experts top-2 + dense residual [hf:Snowflake/snowflake-arctic-base].
bf16 Adam moments + FSDP keep the optimizer state inside v5e HBM."""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="arctic-480b", family="moe", n_layers=35, d_model=7168,
    n_heads=56, n_kv_heads=8, d_ff=4864, vocab_size=32000,
    n_experts=128, top_k=2, moe_dense_ff=4864,
    moment_dtype="bfloat16", fsdp=True,
)

SMOKE = FULL.replace(
    name="arctic-smoke", n_layers=2, d_model=64, n_heads=8, n_kv_heads=2,
    d_ff=96, vocab_size=512, n_experts=8, top_k=2, moe_dense_ff=96,
    param_dtype="float32", compute_dtype="float32", logits_chunk=32,
    moment_dtype="float32")
