"""minicpm-2b [dense]: 40L d=2304 36H (GQA kv=36) d_ff=5760 vocab=122753.
WSD schedule, llama-like arch [arXiv:2404.06395]."""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="minicpm-2b", family="dense", n_layers=40, d_model=2304,
    n_heads=36, n_kv_heads=36, d_ff=5760, vocab_size=122753,
    rope_theta=10000.0, tie_embeddings=True,
)

SMOKE = FULL.replace(
    name="minicpm-smoke", n_layers=2, d_model=72, n_heads=6, n_kv_heads=6,
    d_ff=144, vocab_size=512, param_dtype="float32",
    compute_dtype="float32", logits_chunk=32)
