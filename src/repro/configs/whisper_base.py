"""whisper-base [audio]: 6L (x2: enc+dec) d=512 8H d_ff=2048 vocab=51865,
enc-dec with conv frontend STUB (precomputed 1500-frame embeddings)
[arXiv:2212.04356]."""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="whisper-base", family="whisper", n_layers=6, d_model=512,
    n_heads=8, n_kv_heads=8, d_ff=2048, vocab_size=51865,
    encoder_layers=6, n_audio_frames=1500,
)

SMOKE = FULL.replace(
    name="whisper-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=512, encoder_layers=2, n_audio_frames=24,
    param_dtype="float32", compute_dtype="float32", logits_chunk=32)
