"""rwkv6-7b [ssm]: 32L d=4096 attention-free, d_ff=14336 vocab=65536.
Finch: data-dependent decay [arXiv:2404.05892]. head_dim (ssm_state) = 64.
"""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="rwkv6-7b", family="rwkv6", n_layers=32, d_model=4096,
    n_heads=64, n_kv_heads=64, d_ff=14336, vocab_size=65536,
    ssm_state=64,
)

SMOKE = FULL.replace(
    name="rwkv6-smoke", n_layers=2, d_model=64, n_heads=8, n_kv_heads=8,
    d_ff=128, vocab_size=512, ssm_state=8, param_dtype="float32",
    compute_dtype="float32", logits_chunk=32)
