"""qwen3-1.7b [dense]: 28L d=2048 16H (GQA kv=8) d_ff=6144 vocab=151936.
qk_norm + GQA [hf:Qwen/Qwen3-8B family; hf]."""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="qwen3-1.7b", family="dense", n_layers=28, d_model=2048,
    n_heads=16, n_kv_heads=8, d_ff=6144, vocab_size=151936,
    head_dim=128, qk_norm=True, rope_theta=1e6, tie_embeddings=True,
)

SMOKE = FULL.replace(
    name="qwen3-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab_size=512, head_dim=16, param_dtype="float32",
    compute_dtype="float32", logits_chunk=32)
