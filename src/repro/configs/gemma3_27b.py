"""gemma3-27b [dense]: 62L d=5376 32H (GQA kv=16) d_ff=21504 vocab=262144.
5:1 local:global attention, 128k context [hf:google/gemma-3 family]."""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="gemma3-27b", family="dense", n_layers=62, d_model=5376,
    n_heads=32, n_kv_heads=16, d_ff=21504, vocab_size=262144,
    head_dim=128, qk_norm=True, rope_theta=1e6, tie_embeddings=True,
    global_every=6, local_window=1024,
)

SMOKE = FULL.replace(
    name="gemma3-smoke", n_layers=6, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab_size=512, head_dim=16, local_window=16,
    param_dtype="float32", compute_dtype="float32", logits_chunk=32)
