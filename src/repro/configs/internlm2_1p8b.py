"""internlm2-1.8b [dense]: 24L d=2048 16H (GQA kv=8) d_ff=8192 vocab=92544.
GQA [arXiv:2403.17297]."""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="internlm2-1.8b", family="dense", n_layers=24, d_model=2048,
    n_heads=16, n_kv_heads=8, d_ff=8192, vocab_size=92544,
    rope_theta=1e6,
)

SMOKE = FULL.replace(
    name="internlm2-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=160, vocab_size=512, param_dtype="float32",
    compute_dtype="float32", logits_chunk=32)
