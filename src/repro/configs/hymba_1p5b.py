"""hymba-1.5b [hybrid]: 32L d=1600 25H (GQA kv=5) d_ff=5504 vocab=32001,
ssm_state=16; parallel attention + mamba heads [arXiv:2411.13676]."""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="hymba-1.5b", family="hymba", n_layers=32, d_model=1600,
    n_heads=25, n_kv_heads=5, d_ff=5504, vocab_size=32001,
    ssm_state=16, hymba_window=1024,
)

SMOKE = FULL.replace(
    name="hymba-smoke", n_layers=2, d_model=60, n_heads=5, n_kv_heads=5,
    d_ff=128, vocab_size=512, ssm_state=4, hymba_window=16,
    param_dtype="float32", compute_dtype="float32", logits_chunk=32)
