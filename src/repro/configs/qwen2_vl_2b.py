"""qwen2-vl-2b [vlm]: 28L d=1536 12H (GQA kv=2) d_ff=8960 vocab=151936,
M-RoPE + dynamic-resolution patch frontend STUB [arXiv:2409.12191]."""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="qwen2-vl-2b", family="vlm", n_layers=28, d_model=1536,
    n_heads=12, n_kv_heads=2, d_ff=8960, vocab_size=151936,
    mrope=True, n_patch_tokens=1024, tie_embeddings=True,
)

SMOKE = FULL.replace(
    name="qwen2-vl-smoke", n_layers=2, d_model=48, n_heads=4,
    n_kv_heads=2, d_ff=96, vocab_size=512, n_patch_tokens=8,
    param_dtype="float32", compute_dtype="float32", logits_chunk=32)
