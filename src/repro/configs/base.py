"""ModelConfig: one schema covering all 10 assigned architectures."""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | rwkv6 | hymba | whisper | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: Optional[int] = None          # default d_model // n_heads
    qk_norm: bool = False                   # qwen3-style
    rope_theta: float = 1_000_000.0
    tie_embeddings: bool = False

    # gemma3-style interleaved local:global attention
    global_every: int = 0                   # 0 = all global; N = every Nth
    local_window: int = 1024

    # MoE
    n_experts: int = 0
    top_k: int = 2
    moe_dense_ff: int = 0                   # arctic dense-residual FFN width
    capacity_factor: float = 1.25

    # SSM / hybrid
    ssm_state: int = 0                      # rwkv6 head dim / hymba state
    hymba_window: int = 1024                # sliding window for hybrid attn
    ssm_chunk: int = 256                    # remat chunk for time scans
    use_wkv_kernel: bool = False            # rwkv serving via Pallas wkv

    # whisper (enc-dec)
    encoder_layers: int = 0
    n_audio_frames: int = 1500

    # vlm
    mrope: bool = False
    n_patch_tokens: int = 1024              # stubbed image-patch prefix

    norm_eps: float = 1e-6
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"

    # runtime / distribution knobs (overridable per run)
    remat: str = "none"                     # none | full | dots
    fsdp: bool = True                       # shard params over data axis too
    moment_dtype: str = "float32"           # AdamW moment dtype (HBM knob)
    logits_chunk: int = 256                 # seq chunk for vocab xent
    scan_layers: bool = True                # lax.scan over stacked layers

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_encdec(self) -> bool:
        return self.family == "whisper"

    @property
    def approx_params(self) -> int:
        """Rough parameter count for roofline MODEL_FLOPS."""
        d, L = self.d_model, self.n_layers
        hd = self.hd
        attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) \
            + (self.n_heads * hd) * d
        if self.family == "rwkv6":
            attn = 5 * d * d + d * d        # r,k,v,g,w projections + out
        if self.family == "moe":
            ffn = 3 * d * self.d_ff * self.n_experts \
                + 3 * d * self.moe_dense_ff
        else:
            ffn = 3 * d * self.d_ff
        if self.family == "hymba":
            attn += 3 * d * d + d * self.ssm_state * 2  # mamba branch
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        enc = self.encoder_layers * (attn + ffn) if self.is_encdec else 0
        cross = self.encoder_layers and L * (attn // 2)
        return L * (attn + ffn) + emb + enc + (cross or 0)

    @property
    def active_params(self) -> int:
        """Active parameters per token (MoE: only top_k experts count)."""
        if self.family != "moe":
            return self.approx_params
        d, L = self.d_model, self.n_layers
        full = self.approx_params
        inactive = L * 3 * d * self.d_ff * (self.n_experts - self.top_k)
        return full - inactive

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)
