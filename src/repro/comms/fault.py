"""Fault state and failure-event plumbing for the training runtime.

A `FaultState` describes the live bandwidth profile of the DP axis. The
training driver holds one, updates it from the failure detector (here: an
injection schedule; in production: NIC health counters / RDMA CM events /
DCN telemetry), and re-builds the jitted train step whenever the state
changes - the analogue of NCCL communicator re-initialization, with the
OptCC planner supplying the new collective schedule in O(pk).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

from repro.core.model import BandwidthProfile
from repro.core.planner import Plan, make_plan


@dataclasses.dataclass(frozen=True)
class FaultState:
    """Static description of DP-axis health; hashable so jit can key on it."""

    axis_size: int
    straggler: Optional[int] = None     # DP index of the degraded member
    ell: float = 1.0                    # slowdown factor (1.0 = healthy)

    @property
    def degraded(self) -> bool:
        return self.straggler is not None and self.ell > 1.0

    def profile(self) -> BandwidthProfile:
        if not self.degraded:
            return BandwidthProfile.healthy(self.axis_size)
        return BandwidthProfile.single_straggler(
            self.axis_size, self.ell, straggler=self.straggler)

    def plan(self, n_elements: int, k: int = 16,
             materialize: bool = False) -> Plan:
        return make_plan(self.profile(), n_elements, k,
                         materialize=materialize)


@dataclasses.dataclass
class FailureInjector:
    """Deterministic failure schedule for tests/examples.

    events: {step: FaultState} - at each listed step the fault state
    changes (e.g. a NIC loss at step 100, repair at step 200).
    """

    axis_size: int
    events: dict[int, FaultState] = dataclasses.field(default_factory=dict)

    def at_step(self, step: int, current: FaultState) -> FaultState:
        return self.events.get(step, current)

    @classmethod
    def nic_loss(cls, axis_size: int, step: int, straggler: int,
                 ell: float, repair_step: Optional[int] = None
                 ) -> "FailureInjector":
        ev = {step: FaultState(axis_size, straggler, ell)}
        if repair_step is not None:
            ev[repair_step] = FaultState(axis_size)
        return cls(axis_size, ev)

    def to_timeline(self, t_per_step: float, base: Optional[FaultState] = None):
        """Bridge to the simulator's `FaultTimeline`: the step-indexed
        injection schedule as per-rank SET events at ``step * t_per_step``
        element-time.

        The injector's schedule is a sequence of whole-cluster states; the
        timeline wants per-rank deltas, so consecutive states are diffed and
        only ranks whose slowdown actually changes emit events (a repair
        emits the explicit return to 1.0). `base` is the state before the
        first event (default: healthy). The result plugs straight into
        `planner.replay` / `detect.estimate_timeline`, letting one injection
        schedule drive both the runtime path and the what-if simulation.
        """
        from repro.core.model import FaultTimeline
        if t_per_step <= 0:
            raise ValueError("t_per_step must be > 0")
        cur = (base if base is not None
               else FaultState(self.axis_size)).profile().slowdown
        triples: list[tuple[float, int, float]] = []
        for step in sorted(self.events):
            nxt = self.events[step].profile().slowdown
            for r, (a, b) in enumerate(zip(cur, nxt)):
                if a != b:
                    triples.append((step * t_per_step, r, b))
            cur = nxt
        return FaultTimeline.make(triples)


class FaultAwareSync:
    """Callable gradient-sync selector used by train.step factories.

    mode 'auto': psum when healthy, optcc_allreduce when degraded.
    """

    def __init__(self, state: FaultState):
        self.state = state

    def grad_sync_kind(self) -> str:
        return "optcc" if self.state.degraded else "psum"
