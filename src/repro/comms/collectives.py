"""Software collectives: the paper's schedules as JAX (shard_map) programs.

XLA's built-in all-reduce assumes symmetric link bandwidth and emits its own
ring/tree schedule. To control the flow structure under degraded links we
express gradient sync as explicit `lax.ppermute` steps inside `shard_map`:

  * ring_reduce_scatter / ring_all_gather - the NCCL ring baseline;
  * optcc_allreduce - OptCC's stage structure for a single degraded member
    of the axis: the straggler's data enters the healthy subring once
    (ordering B: "the straggler uploads its local value first"), the
    p-1 healthy members reduce-scatter + allgather among themselves on
    their full-bandwidth links, and exactly one flow returns the result to
    the straggler. The straggler link therefore carries 2n elements total -
    the information-theoretic minimum (Lemma 5) - instead of the 2n(p-1)/p
    it would carry inside a symmetric ring.

On real hardware the fine-grained segment pipelining of Section 4.2 is the
transport layer's concern (core.schedule / core.simulator model it); at the
XLA level what matters is which links carry how many bytes, which is what
this module controls. Functional equivalence with psum is tested on 8 host
devices (tests/test_collectives_multidev.py).

Also here: hierarchical cross-pod psum and int8-compressed gradient sync
with error feedback (distributed-optimization extras used by train.step).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax


def _axis_size(axis_name: str) -> int:
    return lax.axis_size(axis_name)


def _healthy_ring(axis_size: int, straggler: int) -> list[int]:
    return [r for r in range(axis_size) if r != straggler]


# ----------------------------------------------------------------------------
# ring reduce-scatter / all-gather over a named axis (NCCL-ring baseline)
# ----------------------------------------------------------------------------

def ring_reduce_scatter(x: jax.Array, axis_name: str) -> jax.Array:
    """Flat-vector ring reduce-scatter; returns this member's reduced chunk.

    x: (n,) identical-shape vector on every axis member (n % p == 0).
    Member i returns chunk (i+1) mod p of sum_j x_j, matching the classic
    ring schedule (Patarasuk-Yuan): at step t member i sends chunk (i-t).
    """
    p = _axis_size(axis_name)
    idx = lax.axis_index(axis_name)
    n = x.shape[0]
    assert n % p == 0, "pad the vector to a multiple of the axis size"
    chunks = x.reshape(p, n // p)
    perm = [(i, (i + 1) % p) for i in range(p)]
    acc = chunks
    for t in range(p - 1):
        send_ix = (idx - t) % p
        send = lax.dynamic_index_in_dim(acc, send_ix, axis=0,
                                        keepdims=False)
        recv = lax.ppermute(send, axis_name, perm)
        recv_ix = (idx - t - 1) % p
        acc = lax.dynamic_update_index_in_dim(
            acc, lax.dynamic_index_in_dim(acc, recv_ix, 0, False) + recv,
            recv_ix, axis=0)
    own = (idx + 1) % p
    return lax.dynamic_index_in_dim(acc, own, 0, keepdims=False)


def ring_all_gather(chunk: jax.Array, axis_name: str) -> jax.Array:
    """Inverse of ring_reduce_scatter: member i contributes chunk (i+1)."""
    p = _axis_size(axis_name)
    idx = lax.axis_index(axis_name)
    out = jnp.zeros((p,) + chunk.shape, chunk.dtype)
    out = lax.dynamic_update_index_in_dim(out, chunk, (idx + 1) % p, axis=0)
    perm = [(i, (i + 1) % p) for i in range(p)]
    cur = chunk
    for t in range(p - 1):
        cur = lax.ppermute(cur, axis_name, perm)
        # after t+1 hops we hold the chunk originating at (idx - t - 1),
        # i.e. chunk index (idx - t) mod p.
        cix = (idx - t) % p
        out = lax.dynamic_update_index_in_dim(out, cur, cix, axis=0)
    return out.reshape(-1)


def ring_allreduce(x: jax.Array, axis_name: str) -> jax.Array:
    """Reference ring AllReduce (== psum) built from the two halves."""
    return ring_all_gather(ring_reduce_scatter(x, axis_name), axis_name)


# ----------------------------------------------------------------------------
# OptCC AllReduce: one degraded axis member
# ----------------------------------------------------------------------------

def optcc_allreduce(x: jax.Array, axis_name: str, straggler: int,
                    axis_size: int) -> jax.Array:
    """AllReduce where axis member `straggler` has a degraded link.

    Flow structure (per the planner's schedule): the straggler sends its
    vector once to its successor on the healthy subring and receives the
    final sum once - total 2n elements over the slow link (the Lemma-5
    minimum). All remaining traffic runs on the p-1 healthy members' ring.

    `straggler` and `axis_size` must be static (the program is re-jitted
    when the fault state changes - the moral equivalent of NCCL
    communicator re-initialization after failover).
    """
    p = axis_size
    if p < 3:
        raise ValueError("optcc_allreduce needs axis size >= 3")
    idx = lax.axis_index(axis_name)
    healthy = _healthy_ring(p, straggler)
    ph = p - 1
    peer = healthy[0]
    n = x.shape[0]
    pad = (-n) % ph
    xp = jnp.pad(x, (0, pad))

    # Stage "S3'" (ordering B): straggler -> peer; peer folds it in.
    from_straggler = lax.ppermute(xp, axis_name, [(straggler, peer)])
    xp = jnp.where(idx == peer, xp + from_straggler, xp)

    # Stages S1/S4 on the healthy subring. Healthy member h = healthy[i]
    # plays ring position i; the straggler executes the same SPMD code but
    # is in no permutation pair, so it moves no data.
    hpos = jnp.where(idx > straggler, idx - 1, idx)      # ring position
    chunks = xp.reshape(ph, -1)
    perm_h = [(healthy[i], healthy[(i + 1) % ph]) for i in range(ph)]

    acc = chunks
    for t in range(ph - 1):                               # reduce-scatter
        send_ix = (hpos - t) % ph
        send = lax.dynamic_index_in_dim(acc, send_ix, 0, False)
        recv = lax.ppermute(send, axis_name, perm_h)
        recv_ix = (hpos - t - 1) % ph
        acc = lax.dynamic_update_index_in_dim(
            acc, lax.dynamic_index_in_dim(acc, recv_ix, 0, False) + recv,
            recv_ix, axis=0)

    own_ix = (hpos + 1) % ph
    cur = lax.dynamic_index_in_dim(acc, own_ix, 0, False)
    out = jnp.zeros_like(chunks)
    out = lax.dynamic_update_index_in_dim(out, cur, own_ix, axis=0)
    for t in range(ph - 1):                               # allgather
        cur = lax.ppermute(cur, axis_name, perm_h)
        cix = (hpos - t) % ph
        out = lax.dynamic_update_index_in_dim(out, cur, cix, axis=0)
    full = out.reshape(-1)

    # Stage "S2'": one healthy member returns the sum to the straggler.
    to_straggler = lax.ppermute(full, axis_name, [(peer, straggler)])
    full = jnp.where(idx == straggler, to_straggler, full)
    return full[:n] if pad else full


def optcc_allreduce_tree(tree, axis_name: str, straggler: int,
                         axis_size: int):
    """OptCC AllReduce over a pytree: flatten-concat, one collective, split.

    Concatenating all gradient leaves into one flat vector both matches the
    paper's single-buffer model and amortizes the per-ppermute latency."""
    leaves, treedef = jax.tree.flatten(tree)
    sizes = [leaf.size for leaf in leaves]
    flat = jnp.concatenate([leaf.reshape(-1).astype(jnp.float32)
                            for leaf in leaves])
    summed = optcc_allreduce(flat, axis_name, straggler, axis_size)
    outs, off = [], 0
    for leaf, size in zip(leaves, sizes):
        outs.append(summed[off:off + size].reshape(leaf.shape)
                    .astype(leaf.dtype))
        off += size
    return jax.tree.unflatten(treedef, outs)


# ----------------------------------------------------------------------------
# hierarchical + compressed gradient sync
# ----------------------------------------------------------------------------

def hierarchical_psum(x: jax.Array, inner_axis: str,
                      outer_axis: Optional[str]) -> jax.Array:
    """psum within the pod, then across pods (DCN-friendly ordering)."""
    y = lax.psum(x, inner_axis)
    if outer_axis is not None:
        y = lax.psum(y, outer_axis)
    return y


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization (scale in fp32)."""
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-30) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(x: jax.Array, axis_name: str,
                    error: Optional[jax.Array] = None
                    ) -> tuple[jax.Array, jax.Array]:
    """AllReduce with int8-compressed allgather half + error feedback.

    reduce-scatter runs at full precision (sums must not saturate); each
    member quantizes its reduced shard to int8 and the shards are
    allgathered at 1/4 the bytes. Returns (result, new_error) where
    new_error is this member's local quantization residual (add it to the
    next step's gradient - standard error-feedback compression).
    """
    p = _axis_size(axis_name)
    n = x.shape[0]
    if error is not None:
        x = x + error
    pad = (-n) % p
    xp = jnp.pad(x, (0, pad))
    shard = lax.psum_scatter(xp.reshape(p, -1), axis_name,
                             scatter_dimension=0, tiled=False)
    q, scale = quantize_int8(shard)
    deq_own = dequantize_int8(q, scale)
    new_error_shard = shard - deq_own
    qs = lax.all_gather(q, axis_name, axis=0)
    scales = lax.all_gather(scale, axis_name, axis=0)
    full = (qs.astype(jnp.float32) * scales[:, None]).reshape(-1)
    # Scatter the residual back to full length for simple state handling.
    idx = lax.axis_index(axis_name)
    err_full = jnp.zeros_like(xp.reshape(p, -1))
    err_full = lax.dynamic_update_index_in_dim(err_full, new_error_shard,
                                               idx, axis=0).reshape(-1)
    return full[:n], err_full[:n]
