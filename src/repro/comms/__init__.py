from repro.comms.collectives import (compressed_psum, dequantize_int8,
                                     hierarchical_psum, optcc_allreduce,
                                     optcc_allreduce_tree, quantize_int8,
                                     ring_all_gather, ring_allreduce,
                                     ring_reduce_scatter)
from repro.comms.fault import FailureInjector, FaultAwareSync, FaultState

__all__ = [
    "ring_reduce_scatter", "ring_all_gather", "ring_allreduce",
    "optcc_allreduce", "optcc_allreduce_tree", "hierarchical_psum",
    "quantize_int8", "dequantize_int8", "compressed_psum",
    "FaultState", "FailureInjector", "FaultAwareSync",
]
