"""jit'd public wrapper for the flash attention kernel."""
from __future__ import annotations

import jax

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import flash_attention_ref


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    bq: int = 128, bkv: int = 128,
                    use_pallas: bool = True,
                    interpret: bool = False) -> jax.Array:
    """Blockwise attention; falls back to the jnp oracle off-TPU."""
    if not use_pallas:
        return flash_attention_ref(q, k, v, causal=causal, window=window)
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  bq=bq, bkv=bkv, interpret=interpret)
