"""Pallas TPU kernel: blockwise (flash) attention with causal/window masks.

The models' dominant compute at training shapes. Grid:
(batch, q_heads, q_blocks, kv_blocks) with the kv dimension innermost and
"arbitrary" (sequential), carrying the online-softmax state (m, l, acc) in
VMEM scratch across kv steps. GQA is handled in the index maps: q head h
reads kv head h // (H // KV). Block shapes are MXU/lane aligned
(multiples of 128 on the seq dims; head_dim rides along whole).

Softmax statistics in fp32 regardless of input dtype.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale, causal, window, bq, bkv, nkv, seq_kv):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)          # (bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)          # (bkv, hd)
    v = v_ref[0, 0].astype(jnp.float32)          # (bkv, hd)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    iq = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
    jk = ki * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
    ok = jk < seq_kv
    if causal:
        ok = ok & (jk <= iq)
    if window > 0:
        ok = ok & (jk > iq - window)
    s = jnp.where(ok, s, NEG_INF)

    m_prev, l_prev = m_ref[...], l_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ki == nkv - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "bq", "bkv", "interpret"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, window: int = 0,
                           bq: int = 128, bkv: int = 128,
                           interpret: bool = False) -> jax.Array:
    """q: (B, Sq, H, hd); k, v: (B, Skv, KV, hd) -> (B, Sq, H, hd)."""
    B, Sq, H, hd = q.shape
    _, Skv, KV, _ = k.shape
    assert H % KV == 0
    rep = H // KV
    scale = 1.0 / (hd ** 0.5)

    bq = min(bq, max(8, Sq))
    bkv = min(bkv, max(8, Skv))
    pad_q = (-Sq) % bq
    pad_kv = (-Skv) % bkv
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
    # (B, H, S, hd) layout for clean blocking
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    nq = qt.shape[2] // bq
    nkv = kt.shape[2] // bkv

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        bq=bq, bkv=bkv, nkv=nkv, seq_kv=Skv)

    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, bkv, hd),
                         lambda b, h, qi, ki: (b, h // rep, ki, 0)),
            pl.BlockSpec((1, 1, bkv, hd),
                         lambda b, h, qi, ki: (b, h // rep, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(qt.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(qt, kt, vt)
    out = out.transpose(0, 2, 1, 3)
    return out[:, :Sq]
