"""Pure-jnp oracle for flash attention: the direct quadratic path."""
from repro.models.attention import direct_attention


def flash_attention_ref(q, k, v, *, causal=True, window=0):
    return direct_attention(q, k, v, causal=causal, window=window)
