"""jit'd public wrapper for the chunk_reduce kernel."""
from __future__ import annotations

import jax

from repro.kernels.chunk_reduce.kernel import (DEFAULT_BLOCK,
                                               chunk_reduce_pallas)
from repro.kernels.chunk_reduce.ref import chunk_reduce_ref


def chunk_reduce(parts: jax.Array, block: int = DEFAULT_BLOCK,
                 use_pallas: bool = True, interpret: bool = False,
                 out_dtype=None) -> jax.Array:
    """Sum W partial buffers: (W, N) -> (N,), fp32 accumulation.

    use_pallas=False falls back to the jnp oracle (the default on
    non-TPU backends unless interpret=True is requested).
    """
    if not use_pallas:
        return chunk_reduce_ref(parts, out_dtype)
    return chunk_reduce_pallas(parts, block=block, interpret=interpret,
                               out_dtype=out_dtype)
