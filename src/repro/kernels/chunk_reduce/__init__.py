from repro.kernels.chunk_reduce.ops import chunk_reduce
