"""Pure-jnp oracle for chunk_reduce."""
import jax.numpy as jnp


def chunk_reduce_ref(parts: jnp.ndarray, out_dtype=None) -> jnp.ndarray:
    """parts: (W, N) -> (N,): fp32-accumulated elementwise sum."""
    out_dtype = out_dtype or parts.dtype
    return parts.astype(jnp.float32).sum(axis=0).astype(out_dtype)
