"""Pallas TPU kernel: multiway chunk reduction (the AllReduce combine op).

The paper's data plane repeatedly applies `acc += incoming_flow` over large
gradient segments (Stage-1 ring hops, Stage-2 straggler folds, star-block
accumulation). On TPU this is an HBM-bandwidth-bound streaming reduce; the
kernel tiles the element axis into lane-aligned VMEM blocks and
fp32-accumulates the W incoming ways per block, so each output element is
written once and each input element read once.

Grid: one program per element block. BlockSpec keeps the W-way stack of
one block resident in VMEM ((W, BLOCK) <= ~4 MB for W<=16, BLOCK=131072
bf16) - within v5e's 128 MB VMEM budget with double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
DEFAULT_BLOCK = 16 * 1024


def _kernel(x_ref, o_ref):
    # x_ref: (W, BLOCK) VMEM; o_ref: (BLOCK,) VMEM
    acc = x_ref[...].astype(jnp.float32).sum(axis=0)
    o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block", "interpret", "out_dtype"))
def chunk_reduce_pallas(parts: jax.Array, block: int = DEFAULT_BLOCK,
                        interpret: bool = False, out_dtype=None):
    W, N = parts.shape
    out_dtype = out_dtype or parts.dtype
    block = min(block, max(LANES, ((N + LANES - 1) // LANES) * LANES))
    pad = (-N) % block
    if pad:
        parts = jnp.pad(parts, ((0, 0), (0, pad)))
    npad = parts.shape[1]
    out = pl.pallas_call(
        _kernel,
        grid=(npad // block,),
        in_specs=[pl.BlockSpec((W, block), lambda i: (0, i))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((npad,), out_dtype),
        interpret=interpret,
    )(parts)
    return out[:N]
