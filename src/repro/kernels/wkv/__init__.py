from repro.kernels.wkv.ops import wkv
