"""Pure-jnp oracle for the rwkv6 wkv recurrence."""
import jax.numpy as jnp
from jax import lax


def wkv_ref(r, k, v, w, u):
    """r,k,v,w: (B, S, H, hd) fp32; u: (H, hd).

    out_t = r_t . (S + u * k_t^T v_t);  S' = diag(w_t) S + k_t^T v_t
    Returns (out (B,S,H,hd), final_state (B,H,hd,hd)).
    """
    B, S, H, hd = r.shape

    def step(state, inp):
        r_t, k_t, v_t, w_t = inp
        kv = jnp.einsum("bhk,bhv->bhkv", k_t, v_t)
        out = jnp.einsum("bhk,bhkv->bhv", r_t,
                         state + u[None, :, :, None] * kv)
        return w_t[..., None] * state + kv, out

    init = jnp.zeros((B, H, hd, hd), jnp.float32)
    state, outs = lax.scan(
        step, init, (r.swapaxes(0, 1), k.swapaxes(0, 1),
                     v.swapaxes(0, 1), w.swapaxes(0, 1)))
    return outs.swapaxes(0, 1), state
