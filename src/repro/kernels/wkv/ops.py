"""jit'd public wrapper for the wkv kernel."""
from __future__ import annotations

from repro.kernels.wkv.kernel import wkv_pallas
from repro.kernels.wkv.ref import wkv_ref


def wkv(r, k, v, w, u, state0=None, use_pallas: bool = True,
        interpret: bool = False):
    if not use_pallas:
        out, state = wkv_ref(r, k, v, w, u)
        return out, state
    return wkv_pallas(r, k, v, w, u, state0, interpret=interpret)
