"""Pallas TPU kernel: rwkv6 wkv recurrence with VMEM-resident state.

The jnp scan pays HBM round-trips for the (hd x hd) per-head state every
token - the dominant memory term of rwkv6-7b training/prefill cells. This
kernel keeps the state in VMEM across the whole sequence block: one grid
program per (batch, head), fori_loop over tokens, one HBM read per input
element and one write per output element.

VMEM budget per program: 4 x (S, hd) inputs + (S, hd) out + (hd, hd)
state; at S=4096, hd=64 fp32 that is ~5.3 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, o_ref,
                sout_ref, *, seq):
    u = u_ref[0]                                   # (hd,)
    state0 = s0_ref[0, 0]                          # (hd, hd)

    def body(t, state):
        r = r_ref[0, t, 0]
        k = k_ref[0, t, 0]
        v = v_ref[0, t, 0]
        w = w_ref[0, t, 0]
        kv = k[:, None] * v[None, :]               # (hd, hd)
        o_ref[0, t, 0] = ((state + u[:, None] * kv) * r[:, None]).sum(0)
        return w[:, None] * state + kv

    state = lax.fori_loop(0, seq, body, state0)
    sout_ref[0, 0] = state


@functools.partial(jax.jit, static_argnames=("interpret",))
def wkv_pallas(r, k, v, w, u, state0=None, interpret: bool = False):
    """r,k,v,w: (B, S, H, hd) fp32; u: (H, hd); state0: (B, H, hd, hd)."""
    B, S, H, hd = r.shape
    if state0 is None:
        state0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    io_spec = pl.BlockSpec((1, S, 1, hd), lambda b, h: (b, 0, h, 0))
    out, sout = pl.pallas_call(
        functools.partial(_wkv_kernel, seq=S),
        grid=(B, H),
        in_specs=[io_spec, io_spec, io_spec, io_spec,
                  pl.BlockSpec((1, hd), lambda b, h: (h, 0)),
                  pl.BlockSpec((1, 1, hd, hd), lambda b, h: (b, h, 0, 0))],
        out_specs=[io_spec,
                   pl.BlockSpec((1, 1, hd, hd), lambda b, h: (b, h, 0, 0))],
        out_shape=[jax.ShapeDtypeStruct((B, S, H, hd), jnp.float32),
                   jax.ShapeDtypeStruct((B, H, hd, hd), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(r, k, v, w, u, state0)
    return out, sout
