"""Chrome-trace (Perfetto / chrome://tracing JSON) export of a simulation.

Layout: one trace *process* per rank; within it one *thread* lane per port
(nic-send, nic-recv, and for multi-GPU schedules nv-send/nv-recv). Every
wire flow becomes one complete ("X") event on its sender's send lane and
one on its receiver's recv lane - ports are exclusive, so events never
overlap within a lane. A final process holds the critical-path lane:
the flows on the path plus ``stall:*`` slices for attributed waits.

Element-time maps 1:1 to trace microseconds (the viewer's native unit);
absolute numbers are model time units, not wall clock.
"""
from __future__ import annotations

import json

from repro.obs.critical_path import critical_path
from repro.obs.telemetry import FlowTelemetry

# tid per (nv, direction): deliberately mirrors the simulator's port id
# low bits so a lane is identifiable from the raw trace.
_LANES = {(False, "s"): 0, (False, "r"): 1, (True, "s"): 2, (True, "r"): 3}
_LANE_NAMES = {0: "nic-send", 1: "nic-recv", 2: "nv-send", 3: "nv-recv"}


def chrome_trace(tele: FlowTelemetry, name: str = "allreduce") -> dict:
    """Build the trace as a JSON-serializable dict."""
    events: list[dict] = []
    cp_pid = tele.p
    for r in range(tele.p):
        events.append({"ph": "M", "name": "process_name", "pid": r, "tid": 0,
                       "args": {"name": f"rank {r}"}})
        lanes = (0, 1, 2, 3) if tele.gpus_per_server > 1 else (0, 1)
        for tid in lanes:
            events.append({"ph": "M", "name": "thread_name", "pid": r,
                           "tid": tid, "args": {"name": _LANE_NAMES[tid]}})
    events.append({"ph": "M", "name": "process_name", "pid": cp_pid,
                   "tid": 0, "args": {"name": "critical path"}})

    for fid in range(tele.nflows):
        if tele.size[fid] <= 0:
            continue
        ts = float(tele.start[fid])
        dur = float(tele.finish[fid]) - ts
        stage = tele.stage_of(fid)
        nv = bool(tele.nv[fid])
        args = {"fid": fid, "src": int(tele.src[fid]),
                "dst": int(tele.dst[fid]), "size": float(tele.size[fid]),
                "stage": stage}
        for rank, d in ((int(tele.src[fid]), "s"), (int(tele.dst[fid]), "r")):
            events.append({"ph": "X", "name": stage, "cat": "flow",
                           "pid": rank, "tid": _LANES[(nv, d)],
                           "ts": ts, "dur": dur, "args": args})

    segments, gaps = critical_path(tele)
    for s in segments:
        if s["finish"] > s["start"]:
            events.append({"ph": "X", "name": s["stage"], "cat": "critical",
                           "pid": cp_pid, "tid": 0, "ts": s["start"],
                           "dur": s["finish"] - s["start"],
                           "args": {"fid": s["fid"]}})
    for g in gaps:
        events.append({"ph": "X", "name": "stall:" + g["stage"],
                       "cat": "critical", "pid": cp_pid, "tid": 0,
                       "ts": g["t0"], "dur": g["t1"] - g["t0"],
                       "args": {"fid": g["fid"]}})

    return {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": {"name": name, "algo": tele.algo,
                          "makespan": tele.makespan, "p": tele.p}}


def write_chrome_trace(tele: FlowTelemetry, path: str,
                       name: str = "allreduce") -> None:
    """Write the trace to `path` (open in chrome://tracing or Perfetto)."""
    with open(path, "w") as fh:
        json.dump(chrome_trace(tele, name=name), fh)
