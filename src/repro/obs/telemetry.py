"""Per-flow telemetry collected from a finished simulation.

`collect` turns a (schedule, SimResult) pair into columnar per-flow records:
start/finish/duration by fid, endpoints, stage tags, the dependency CSR, and
per-port busy intervals. Everything is *derived* from the times the
simulator already computed - collection never re-times anything, so results
with and without telemetry are IEEE-754 identical by construction.

Port id encoding follows the simulator: ``rank * 4 + (2 if nvlink) +
(1 if recv side)``.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.flowvec import FlowArrays
from repro.core.model import STAGE_NAMES, Schedule


def stage_name(sid: int) -> str:
    """Human name for a stage id; untagged schedules report 'UNK'."""
    return STAGE_NAMES[sid] if 0 <= sid < len(STAGE_NAMES) else "UNK"


@dataclasses.dataclass
class FlowTelemetry:
    """Columnar per-flow telemetry, indexed by fid (0..N-1).

    `wire` marks flows that occupy ports (size > 0); zero-size self-stores
    are bookkeeping and never appear in port interval accounting.
    """

    makespan: float
    p: int                     # ranks
    gpus_per_server: int
    algo: str                  # schedule.meta["algo"] (or "?")
    start: np.ndarray          # float64 [N]
    finish: np.ndarray         # float64 [N]
    size: np.ndarray           # float64 [N]
    src: np.ndarray            # int64 [N]
    dst: np.ndarray            # int64 [N]
    nv: np.ndarray             # bool [N]
    stage_ids: np.ndarray      # int16 [N]; -1 = untagged
    dep_indptr: np.ndarray     # int64 [N+1]
    dep_indices: np.ndarray    # int64 [nnz]

    @property
    def nflows(self) -> int:
        return len(self.size)

    @property
    def duration(self) -> np.ndarray:
        return self.finish - self.start

    @property
    def wire(self) -> np.ndarray:
        return self.size > 0

    def deps_of(self, fid: int) -> np.ndarray:
        return self.dep_indices[self.dep_indptr[fid]:self.dep_indptr[fid + 1]]

    def stage_of(self, fid: int) -> str:
        return stage_name(int(self.stage_ids[fid]))

    def sport(self, fid: int) -> int:
        return int(self.src[fid]) * 4 + int(self.nv[fid]) * 2

    def rport(self, fid: int) -> int:
        return int(self.dst[fid]) * 4 + int(self.nv[fid]) * 2 + 1


def collect(schedule: Schedule, result) -> FlowTelemetry:
    """Build FlowTelemetry from a simulated schedule.

    `result` is a `core.simulator.SimResult`; its lazily-materialized
    start/finish dicts are read here (the one place the off-path laziness is
    paid for, which is why telemetry is opt-in).
    """
    fa = schedule.arrays if schedule.arrays is not None \
        else FlowArrays.from_schedule(schedule)
    n = fa.nflows
    s, f = result.start, result.finish
    start = np.fromiter((s[i] for i in range(n)), np.float64, count=n)
    finish = np.fromiter((f[i] for i in range(n)), np.float64, count=n)
    sids = schedule.meta.get("stage_ids")
    stage_ids = np.asarray(sids, np.int16) if sids is not None \
        else np.full(n, -1, np.int16)
    if len(stage_ids) != n:
        raise ValueError(
            f"stage_ids length {len(stage_ids)} != {n} flows")
    return FlowTelemetry(
        makespan=result.makespan,
        p=schedule.profile.p,
        gpus_per_server=schedule.profile.gpus_per_server,
        algo=str(schedule.meta.get("algo", "?")),
        start=start, finish=finish,
        size=fa.size, src=fa.src, dst=fa.dst, nv=fa.nv,
        stage_ids=stage_ids,
        dep_indptr=fa.dep_indptr, dep_indices=fa.dep_indices)


def port_intervals(tele: FlowTelemetry) -> dict[tuple, np.ndarray]:
    """{(kind, rank, dir): (m, 3) array of [start, finish, fid] rows},
    sorted by start. Ports are exclusive, so each port's intervals are
    non-overlapping (up to shared endpoints); tests pin this invariant.
    """
    w = np.nonzero(tele.wire)[0]
    out: dict[tuple, np.ndarray] = {}
    if not len(w):
        return out
    nvw = tele.nv[w].astype(np.int64)
    for pid_arr, d in ((tele.src[w] * 4 + nvw * 2, "s"),
                      (tele.dst[w] * 4 + nvw * 2 + 1, "r")):
        for pid in np.unique(pid_arr):
            sel = w[pid_arr == pid]
            o = np.argsort(tele.start[sel], kind="stable")
            sel = sel[o]
            kind = "nv" if pid & 2 else "nic"
            out[(kind, int(pid) // 4, d)] = np.column_stack(
                (tele.start[sel], tele.finish[sel],
                 sel.astype(np.float64)))
    return out


def port_utilization(tele: FlowTelemetry) -> dict[tuple, float]:
    """{(kind, rank, dir): busy fraction of the makespan}."""
    if tele.makespan <= 0:
        return {}
    w = np.nonzero(tele.wire)[0]
    busy: dict[tuple, float] = {}
    durs = tele.finish[w] - tele.start[w]
    nvw = tele.nv[w]
    for i, fid in enumerate(w):
        kind = "nv" if nvw[i] else "nic"
        for key in ((kind, int(tele.src[fid]), "s"),
                    (kind, int(tele.dst[fid]), "r")):
            busy[key] = busy.get(key, 0.0) + float(durs[i])
    return {k: v / tele.makespan for k, v in busy.items()}
