"""Critical-path walk and per-stage attribution of a simulated makespan.

The walk starts at the last-finishing flow and steps backward through
whatever made each flow start when it did: its latest-finishing dependency,
or the latest flow that occupied one of its ports up to its start time
(ports are exclusive, so that flow is the binding resource conflict). When
even the best predecessor finished strictly before the flow started, the
remaining wait is a *stall* (slot release in the slotted schedules) and is
booked against the waiting flow's stage as ``stall:<stage>``.

The resulting segments and gaps tile [0, makespan] with no overlap, so the
per-stage sums telescope to the simulated total exactly (floating-point
summation error only - a few ulps, far inside the 1e-9 relative tolerance
the tests and artifact validator pin).
"""
from __future__ import annotations

import bisect
import math

import numpy as np

from repro.obs.telemetry import FlowTelemetry


def _port_index(tele: FlowTelemetry) -> dict[int, tuple[list, list]]:
    """port id -> (finish times sorted ascending, fids in that order),
    wire flows only. Lets the walk binary-search 'latest flow on this port
    finishing at or before t'."""
    w = np.nonzero(tele.wire)[0]
    idx: dict[int, tuple[list, list]] = {}
    if not len(w):
        return idx
    nvw = tele.nv[w].astype(np.int64)
    for pid_arr in (tele.src[w] * 4 + nvw * 2,
                    tele.dst[w] * 4 + nvw * 2 + 1):
        for pid in np.unique(pid_arr):
            sel = w[pid_arr == pid]
            o = np.argsort(tele.finish[sel], kind="stable")
            sel = sel[o]
            idx[int(pid)] = (tele.finish[sel].tolist(), sel.tolist())
    return idx


def critical_path(tele: FlowTelemetry) -> tuple[list[dict], list[dict]]:
    """Walk the chain that determined the makespan.

    Returns (segments, gaps), both in increasing-time order:
      segments: {"fid", "stage", "start", "finish"} - flows on the path;
      gaps:     {"fid", "stage", "t0", "t1"} - idle waits immediately
                before segment `fid` started (release stalls, or the lead-in
                before the first flow).
    Together they tile [0, makespan] without overlap.
    """
    n = tele.nflows
    if n == 0 or tele.makespan <= 0:
        return [], []
    pindex = _port_index(tele)
    start, finish = tele.start, tele.finish
    cur = int(np.argmax(finish))
    segments: list[dict] = []
    gaps: list[dict] = []
    while True:
        segments.append({"fid": cur, "stage": tele.stage_of(cur),
                         "start": float(start[cur]),
                         "finish": float(finish[cur])})
        best, best_t = -1, -math.inf
        for d in tele.deps_of(cur).tolist():
            t = float(finish[d])
            if t > best_t or (t == best_t and d < best):
                best, best_t = d, t
        if tele.size[cur] > 0:
            # Latest flow to occupy either of cur's ports before it started.
            for pid in (tele.sport(cur), tele.rport(cur)):
                fin_s, fid_s = pindex[pid]
                j = bisect.bisect_right(fin_s, float(start[cur])) - 1
                if j >= 0:
                    d, t = fid_s[j], fin_s[j]
                    if d != cur and (t > best_t
                                     or (t == best_t and d < best)):
                        best, best_t = d, t
        if best < 0:
            if start[cur] > 0.0:
                gaps.append({"fid": cur, "stage": tele.stage_of(cur),
                             "t0": 0.0, "t1": float(start[cur])})
            break
        if best_t < start[cur]:
            gaps.append({"fid": cur, "stage": tele.stage_of(cur),
                         "t0": best_t, "t1": float(start[cur])})
        cur = best
    segments.reverse()
    gaps.reverse()
    return segments, gaps


def stage_breakdown(tele: FlowTelemetry) -> dict[str, float]:
    """Makespan attributed to stages along the critical path.

    Keys are stage names (plus ``stall:<stage>`` for waits); values are
    absolute element-time contributions summing to the makespan. Zero-sum
    buckets (self-store hops) are dropped.
    """
    segments, gaps = critical_path(tele)
    parts: dict[str, list[float]] = {}
    for s in segments:
        parts.setdefault(s["stage"], []).append(s["finish"] - s["start"])
    for g in gaps:
        parts.setdefault("stall:" + g["stage"], []).append(g["t1"] - g["t0"])
    out = {k: math.fsum(v) for k, v in parts.items()}
    return {k: v for k, v in out.items() if v != 0.0}
