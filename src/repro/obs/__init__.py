"""Observability layer: per-flow telemetry, critical-path stage attribution
and Chrome-trace export for the flow simulator.

Strictly opt-in: nothing here is imported by the simulator's timing paths,
and `simulate(schedule, telemetry=True)` derives everything post-hoc from
the start/finish times the simulator already records - enabling telemetry
cannot change a single bit of any simulated timing.
"""
from repro.obs.critical_path import critical_path, stage_breakdown
from repro.obs.telemetry import (FlowTelemetry, collect, port_intervals,
                                 port_utilization, stage_name)
from repro.obs.trace import chrome_trace, write_chrome_trace

__all__ = [
    "FlowTelemetry",
    "collect",
    "port_intervals",
    "port_utilization",
    "stage_name",
    "critical_path",
    "stage_breakdown",
    "chrome_trace",
    "write_chrome_trace",
]
