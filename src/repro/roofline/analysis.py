"""Roofline terms from compiled dry-run artifacts.

Hardware model (TPU v5e target):
  peak bf16 compute : 197 TFLOP/s per chip
  HBM bandwidth     : 819 GB/s per chip
  ICI link bandwidth: ~50 GB/s per link

  compute term    = HLO_FLOPs / peak
  memory term     = HLO_bytes / HBM_bw
  collective term = collective_bytes / link_bw

All three inputs come from repro.roofline.hlo_parse (loop-trip-aware
analysis of compiled.as_text(); see that module).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s/link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

@dataclasses.dataclass
class Roofline:
    flops: float                  # per device
    bytes_hbm: float              # per device
    bytes_collective: float       # per device
    model_flops: float            # 6*N*D (active params), whole step
    chips: int

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_hbm / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.bytes_collective / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def bound_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs * chips): remat/redundancy waste."""
        total = self.flops * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the roofline the step achieves if it runs exactly at
        the binding resource: useful model FLOPs per second at bound_time
        over the chips' peak."""
        if self.bound_time == 0:
            return 0.0
        achieved = self.model_flops / self.bound_time / self.chips
        return achieved / PEAK_FLOPS

    def to_dict(self) -> dict:
        return {
            "flops_per_device": self.flops,
            "hbm_bytes_per_device": self.bytes_hbm,
            "collective_bytes_per_device": self.bytes_collective,
            "model_flops": self.model_flops,
            "chips": self.chips,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant,
            "useful_flops_fraction": self.useful_flops_fraction,
            "roofline_fraction": self.roofline_fraction,
        }
