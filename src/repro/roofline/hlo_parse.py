"""Post-optimization HLO text analysis with loop-trip multipliers.

The CPU backend's compiled.cost_analysis() counts while-loop bodies ONCE,
which under-reports every lax.scan (layers, microbatches, attention/loss
chunks) by its trip count. This module re-derives the roofline inputs from
compiled.as_text():

  * computations are split brace-aware; `calls=`/`body=`/`condition=`
    edges build the call graph;
  * each while's trip count is recovered from the constant in its
    condition computation (scan loops compare an induction var against a
    constant);
  * multiplier(comp) = product of trip counts on the call path;
  * FLOPs: 2 * prod(result_shape) * K for every dot (K from
    lhs_contracting_dims and the operand symbol table);
  * HBM bytes: sum of result+operand buffer bytes of every top-level op in
    non-fused computations (fusion internals touch no HBM);
  * collective bytes: operand bytes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute ops.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([\d,]*)\]")
_DEF_RE = re.compile(r"^(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")
_OPND_RE = re.compile(r"%([\w\.\-]+)")

FREE_OPS = ("tuple(", "get-tuple-element(", "parameter(", "constant(",
            "bitcast(", "copy(", "after-all(", "partition-id(")


def _shapes_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _first_shape(text: str):
    m = _SHAPE_RE.search(text)
    if not m:
        return None
    dt, dims = m.groups()
    shape = [int(d) for d in dims.split(",")] if dims else []
    return dt, shape


@dataclasses.dataclass
class HloAnalysis:
    flops: float
    hbm_bytes: float
    collective_bytes: float
    collective_by_kind: dict
    n_collectives: int
    trip_counts: dict
    warnings: list


def analyze_hlo(text: str) -> HloAnalysis:
    # --- split into computations (computations are never nested) --------
    comps: dict[str, list[str]] = {}
    headers: dict[str, str] = {}
    cur = None
    for raw in text.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        if not stripped or stripped.startswith("//"):
            continue
        if stripped.endswith("{") and (stripped.startswith("%")
                                       or stripped.startswith("ENTRY")):
            name = stripped.split()[0 if not stripped.startswith("ENTRY")
                                    else 1].lstrip("%")
            cur = name
            comps[cur] = []
            headers[cur] = stripped
        elif stripped == "}" or stripped.startswith("} "):
            cur = None
        elif cur is not None:
            comps[cur].append(stripped)

    warnings: list[str] = []

    # --- symbol tables: value name -> "dtype[shape]" string -------------
    symtab: dict[str, dict[str, str]] = {}
    for cname, lines in comps.items():
        tab: dict[str, str] = {}
        hdr = headers[cname]
        # parameters in the header: "pname: dtype[shape]"
        for pm in re.finditer(r"([\w\.\-]+)\s*:\s*([^,()]+[\]\}])", hdr):
            tab[pm.group(1)] = pm.group(2)
        for ln in lines:
            m = _DEF_RE.match(ln)
            if m:
                # the defining type is the text right after '='
                tab[m.group(1)] = m.group(2)
        symtab[cname] = tab

    # --- call graph (caller -> callee) and while trip counts ------------
    callers: dict[str, list[str]] = defaultdict(list)
    trip: dict[str, int] = {}
    for cname, lines in comps.items():
        for ln in lines:
            for m in re.finditer(r"(?:calls|to_apply|body|condition)="
                                 r"%?([\w\.\-]+)", ln):
                callee = m.group(1)
                if callee in comps:
                    callers[callee].append(cname)
            if " while(" in ln:
                mb = re.search(r"body=%?([\w\.\-]+)", ln)
                mc = re.search(r"condition=%?([\w\.\-]+)", ln)
                count = None
                if mc and mc.group(1) in comps:
                    consts = [int(x) for cl in comps[mc.group(1)]
                              for x in re.findall(r"constant\((\d+)\)", cl)]
                    if consts:
                        count = max(consts)
                if count is None:
                    warnings.append(f"unknown trip for {mb and mb.group(1)}")
                    count = 1
                if mb:
                    trip[mb.group(1)] = count
                    if mc:
                        trip[mc.group(1)] = count

    import functools

    @functools.lru_cache(maxsize=None)
    def multiplier(cname: str) -> int:
        own = trip.get(cname, 1)
        cs = callers.get(cname, [])
        if not cs:
            return own
        return own * max(multiplier(c) for c in set(cs) if c != cname)

    # --- fused computations: internals are HBM-free ----------------------
    fused = set()
    for cname, lines in comps.items():
        for ln in lines:
            if re.search(r"\bfusion\(", ln):
                m = re.search(r"calls=%?([\w\.\-]+)", ln)
                if m:
                    fused.add(m.group(1))
            if "custom_call_target" in ln and "calls=" in ln:
                m = re.search(r"calls=%?([\w\.\-]+)", ln)
                if m:
                    fused.add(m.group(1))

    flops = 0.0
    hbm = 0.0
    coll_by_kind: dict[str, float] = {}
    n_coll = 0

    for cname, lines in comps.items():
        mult = multiplier(cname)
        tab = symtab[cname]
        in_fused = cname in fused
        for ln in lines:
            # ---- FLOPs from dots (count fused or not) -------------------
            dm = re.search(r"=\s*(\S+)\s+dot\(([^)]*)\)", ln)
            if dm:
                res = _first_shape(dm.group(1))
                opnds = _OPND_RE.findall(dm.group(2))
                lc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ln)
                k = 1
                if res and opnds and lc and opnds[0] in tab:
                    lhs = _first_shape(tab[opnds[0]])
                    if lhs:
                        for d in (lc.group(1).split(",")
                                  if lc.group(1) else []):
                            di = int(d)
                            if di < len(lhs[1]):
                                k *= lhs[1][di]
                    n_res = 1
                    for d in res[1]:
                        n_res *= d
                    flops += 2.0 * n_res * k * mult
                continue
            # ---- collectives -------------------------------------------
            hit = None
            for kind in _COLLECTIVES:
                if re.search(rf"\b{kind}(-start)?\(", ln):
                    hit = kind
                    break
            if hit:
                m = re.search(r"\(([^)]*)\)", ln.partition("=")[2])
                b = 0
                if m:
                    for op in _OPND_RE.findall(m.group(1)):
                        if op in tab:
                            b += _shapes_bytes(_result_type(tab[op]))
                if b == 0:
                    b = _shapes_bytes(_result_type(
                        ln.partition("=")[2].strip()))
                coll_by_kind[hit] = coll_by_kind.get(hit, 0.0) + b * mult
                n_coll += 1
                hbm += 2.0 * b * mult   # collectives also touch HBM
                continue
            # ---- HBM traffic (top-level, non-fused ops only) ------------
            if in_fused:
                continue
            md = _DEF_RE.match(ln)
            if not md:
                continue
            body = md.group(2)
            # first parenthesized call in the body identifies the op
            toks = body.split("(")[0].split()
            head = (toks[-1] + "(") if toks else ""
            if not head or any(head == f for f in FREE_OPS):
                continue
            res_b = _shapes_bytes(_result_type(body))
            margs = re.search(r"\(([^)]*)\)", body)
            opnds = _OPND_RE.findall(margs.group(1)) if margs else []
            if head in ("dynamic-slice(", "slice(", "gather(",
                        "broadcast(", "iota(", "reduce(", "reverse(",
                        "pad("):
                # reads only the sliced/produced region, not the operand
                b = 2 * res_b
            elif head == "dynamic-update-slice(":
                upd = _shapes_bytes(_result_type(tab[opnds[1]])) \
                    if len(opnds) > 1 and opnds[1] in tab else res_b
                b = 2 * upd           # read-modify-write of the region
            elif head == "scatter(":
                upd = _shapes_bytes(_result_type(tab[opnds[2]])) \
                    if len(opnds) > 2 and opnds[2] in tab else res_b
                b = 2 * upd
            elif head == "while(":
                b = 0                 # carried buffers alias in place
            else:
                # In-place accumulation fusions (scan-output writes,
                # grad accumulators): an operand with the same type as the
                # result aliases it; traffic is only the updated region,
                # approximated by the remaining operands' bytes.
                op_types = [_result_type(tab[o]) for o in opnds
                            if o in tab]
                res_t = _result_type(body)
                if res_t in op_types and head == "fusion(":
                    others = sum(_shapes_bytes(t) for t in op_types
                                 if t != res_t)
                    b = 2 * others
                else:
                    b = res_b + sum(_shapes_bytes(t) for t in op_types)
            hbm += b * mult

    return HloAnalysis(flops=flops, hbm_bytes=hbm,
                       collective_bytes=sum(coll_by_kind.values()),
                       collective_by_kind=coll_by_kind,
                       n_collectives=n_coll,
                       trip_counts=trip, warnings=warnings[:20])


def _result_type(def_text: str) -> str:
    """The leading 'dtype[shape]' (or tuple of them) of a definition."""
    m = re.match(r"\s*(\([^)]*\)|\S+)", def_text)
    return m.group(1) if m else ""


def top_flop_ops(text: str, k: int = 15) -> list[tuple[float, str, str]]:
    """Debug helper: the k largest FLOP contributors (flops, comp, line)."""
    # reuse analyze_hlo's internals via a light re-parse
    import heapq
    contributions = []
    a = analyze_hlo(text)   # builds trip counts; we re-walk for detail
    # quick re-walk
    comps, cur = {}, None
    for raw in text.splitlines():
        s = raw.strip()
        if s.endswith("{") and (s.startswith("%") or s.startswith("ENTRY")):
            cur = s.split()[0 if not s.startswith("ENTRY") else 1].lstrip("%")
            comps[cur] = []
        elif s == "}":
            cur = None
        elif cur:
            comps[cur].append(s)
    # naive: approximate multiplier by trip counts product on name match
    def mult(c):
        m = a.trip_counts.get(c, 1)
        return m
    for cname, lines in comps.items():
        tab = {}
        for ln in lines:
            mm = _DEF_RE.match(ln)
            if mm:
                tab[mm.group(1)] = mm.group(2)
        for ln in lines:
            dm = re.search(r"=\s*(\S+)\s+dot\(([^)]*)\)", ln)
            if not dm:
                continue
            res = _first_shape(dm.group(1))
            opnds = _OPND_RE.findall(dm.group(2))
            lc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ln)
            kk = 1
            if res and opnds and lc and opnds[0] in tab:
                lhs = _first_shape(tab[opnds[0]])
                if lhs:
                    for d in (lc.group(1).split(",") if lc.group(1) else []):
                        if int(d) < len(lhs[1]):
                            kk *= lhs[1][int(d)]
                n = 1
                for d in res[1]:
                    n *= d
                contributions.append((2.0 * n * kk * mult(cname), cname,
                                      ln[:140]))
    return heapq.nlargest(k, contributions)
