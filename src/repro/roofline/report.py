"""Render the roofline table from the dry-run JSON cache.

Usage: PYTHONPATH=src python -m repro.roofline.report [--mesh single]
Emits a markdown table (stdout) used verbatim in EXPERIMENTS.md.
"""
from __future__ import annotations

import argparse
import json
import pathlib

OUT_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def load(mesh: str, tag: str = "baseline") -> list[dict]:
    recs = []
    for f in sorted(OUT_DIR.glob(f"*__{mesh}__{tag}.json")):
        recs.append(json.loads(f.read_text()))
    return recs


def fmt_bytes(b):
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if b < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def one_liner(rec) -> str:
    """What would move the dominant term down - rule-based suggestion."""
    if rec["status"] != "ok":
        return ""
    r = rec["roofline"]
    dom = r["dominant"]
    shape = rec["shape"]
    arch = rec["arch"]
    if dom == "memory" and shape.startswith("train"):
        if arch.startswith("rwkv"):
            return ("wkv state round-trips dominate; the Pallas wkv kernel "
                    "keeps state in VMEM")
        return ("attention/activation materialization dominates; flash "
                "kernel + fewer stored residuals")
    if dom == "memory" and "decode" in shape or "long" in shape:
        return "KV-cache reads dominate (expected for decode); quantize KV"
    if dom == "memory":
        return "activation streaming; fuse/flash attention"
    if dom == "collective":
        return "resharding traffic; fewer FSDP gathers or bigger microbatch"
    return "compute-bound: good; raise per-chip batch or quantize"


def table(mesh: str, tag: str = "baseline") -> str:
    rows = [
        "| arch | shape | status | t_comp (s) | t_mem (s) | t_coll (s) | "
        "dominant | useful-FLOPs | roofline-frac | bottleneck note |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for rec in load(mesh, tag):
        if rec["status"] == "skipped":
            rows.append(
                f"| {rec['arch']} | {rec['shape']} | skipped | - | - | - | "
                f"- | - | - | {rec['reason']} |")
            continue
        if rec["status"] != "ok":
            rows.append(f"| {rec['arch']} | {rec['shape']} | ERROR | "
                        f"- | - | - | - | - | - | {rec.get('error','')} |")
            continue
        r = rec["roofline"]
        rows.append(
            f"| {rec['arch']} | {rec['shape']} | ok "
            f"| {r['t_compute_s']:.3g} | {r['t_memory_s']:.3g} "
            f"| {r['t_collective_s']:.3g} | {r['dominant']} "
            f"| {r['useful_flops_fraction']:.2f} "
            f"| {r['roofline_fraction']:.2e} | {one_liner(rec)} |")
    return "\n".join(rows)


def memory_table(mesh: str, tag: str = "baseline") -> str:
    rows = ["| arch | shape | params/dev | opt/dev | cache/dev | "
            "HLO flops/dev | coll bytes/dev | dominant coll |",
            "|---|---|---|---|---|---|---|---|"]
    for rec in load(mesh, tag):
        if rec["status"] != "ok":
            continue
        info = rec.get("info", {})
        coll = rec.get("collectives", {})
        by_kind = coll.get("bytes_by_kind", {})
        dom = max(by_kind, key=by_kind.get) if by_kind else "-"
        rows.append(
            f"| {rec['arch']} | {rec['shape']} "
            f"| {fmt_bytes(info.get('params_bytes_per_device', 0))} "
            f"| {fmt_bytes(info.get('opt_bytes_per_device', 0))} "
            f"| {fmt_bytes(info.get('cache_bytes_per_device', 0))} "
            f"| {rec['roofline']['flops_per_device']:.2e} "
            f"| {fmt_bytes(coll.get('total_bytes', 0))} | {dom} |")
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--memory", action="store_true")
    args = ap.parse_args()
    if args.memory:
        print(memory_table(args.mesh, args.tag))
    else:
        print(table(args.mesh, args.tag))


if __name__ == "__main__":
    main()
