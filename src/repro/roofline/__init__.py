from repro.roofline.analysis import (Roofline, PEAK_FLOPS, HBM_BW, ICI_BW)
from repro.roofline.hlo_parse import HloAnalysis, analyze_hlo
