"""Struct-of-arrays flow representation + the vectorized simulator fast path.

The event loop in `core.simulator` costs ~20us per flow in Python; at the
p>=1024 scale of the paper's Section 4.3 claim a schedule has millions of
flows, so the sweep needs a fast path. For two schedule families the event
loop's behaviour is *forced*, which turns simulation into a max-plus
recurrence that numpy can evaluate in blocks:

  * ring with FIFO send sequencing (`core.ring`): every flow's start time is
    exactly max(release, finish[deps]) because the FIFO deps serialize each
    send port and each recv port only ever hears from one sender - the
    schedule is contention-free, so greedy dispatch cannot deviate;
  * slotted OptCC (`core.schedule._optcc_single_slotted`) under
    ``meta["port_inorder"]``: each port serves its flows in (pri, fid)
    order, so a flow starts exactly at max(release, finish[deps],
    finish[port predecessors]).

Generators that satisfy one of these contracts tag their schedules
``meta["vec_exact"] = True``; `simulate` then routes here, and
tests/test_vectorized_equivalence.py enforces bit-identical results against
`simulate_reference` (same IEEE operations: max of the same operands, then
one addition - no reassociation anywhere).

The recurrence is evaluated in flow-graph order with adaptive blocking: a
block of consecutive flows can be computed in one numpy step iff none of
them depends (data dep or port predecessor) on a flow inside the block.
`maxsrc` (the latest in-edge per flow) makes the split point a vectorized
scan; structured schedules yield blocks of ~p flows, so the Python overhead
is O(num_flows / p).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.model import Schedule


@dataclasses.dataclass
class FlowArrays:
    """Columnar flow graph indexed by fid (fids must be 0..N-1).

    `pri` uses NaN for "unset" (fid order); `nv` marks NVLink flows.
    Dependencies are CSR: flow i's deps are
    ``dep_indices[dep_indptr[i]:dep_indptr[i+1]]``.
    """

    src: np.ndarray          # int64 [N]
    dst: np.ndarray          # int64 [N]
    size: np.ndarray         # float64 [N]
    release: np.ndarray      # float64 [N]
    pri: np.ndarray          # float64 [N], NaN = unset
    nv: np.ndarray           # bool [N]
    dep_indptr: np.ndarray   # int64 [N+1]
    dep_indices: np.ndarray  # int64 [nnz]

    @property
    def nflows(self) -> int:
        return len(self.size)

    @classmethod
    def from_schedule(cls, schedule: Schedule) -> "FlowArrays":
        """Convert Flow lists to arrays (fids must form a 0..N-1 range)."""
        nic, nv = schedule.nic_flows, schedule.nvlink_flows
        n = len(nic) + len(nv)
        src = np.empty(n, np.int64)
        dst = np.empty(n, np.int64)
        size = np.empty(n, np.float64)
        release = np.empty(n, np.float64)
        pri = np.empty(n, np.float64)
        nvf = np.zeros(n, bool)
        counts = np.zeros(n + 1, np.int64)
        seen = 0
        for flows, is_nv in ((nic, False), (nv, True)):
            for f in flows:
                i = f.fid
                if not 0 <= i < n:
                    raise ValueError(f"fid {i} outside 0..{n - 1}")
                src[i] = f.src
                dst[i] = f.dst
                size[i] = f.size
                release[i] = f.release
                pri[i] = np.nan if f.pri is None else f.pri
                nvf[i] = is_nv
                counts[i + 1] = len(f.deps)
                seen += 1
        if seen != n:
            raise ValueError("duplicate fids")
        indptr = np.cumsum(counts)
        indices = np.empty(indptr[-1], np.int64)
        for flows in (nic, nv):
            for f in flows:
                if f.deps:
                    a = indptr[f.fid]
                    indices[a:a + len(f.deps)] = f.deps
        return cls(src=src, dst=dst, size=size, release=release, pri=pri,
                   nv=nvf, dep_indptr=indptr, dep_indices=indices)


def _port_predecessors(order_pos: np.ndarray, port_id: np.ndarray,
                       pred: np.ndarray) -> None:
    """pred[pos] = previous position using the same port (wire flows only).

    `order_pos` are processing positions in increasing order; a stable sort
    by port id groups each port's flows while keeping that order, so the
    predecessor is just the previous element within each group.
    """
    o = np.argsort(port_id, kind="stable")
    ps = order_pos[o]
    ids = port_id[o]
    same = ids[1:] == ids[:-1]
    pred[ps[1:][same]] = ps[:-1][same]


def _segmented_finish(s: np.ndarray, sizes: np.ndarray, lmat: np.ndarray,
                      breaks: np.ndarray) -> np.ndarray:
    """Finish times of NIC wire flows under piecewise-constant rates.

    `s` are start times, `sizes` remaining-element budgets, `lmat[k, i]` the
    effective slowdown max(l_src, l_dst) of flow i during segment k, and
    `breaks` the segment boundaries (len(breaks) == lmat.shape[0] - 1).

    Mirrors the scalar event loops' re-timing arithmetic op-for-op: a flow
    finishing exactly at a breakpoint completes under the old rate (<= hi),
    a flow starting exactly at a breakpoint uses the new rate (strict
    t < hi), and partial segments carry ``rem = max(rem - (hi-t)/l, 0)``
    elements forward - that is what keeps vec and scalar runs bit-identical
    under timelines (tests/test_replay.py).
    """
    t = s.copy()
    rem = sizes.astype(np.float64).copy()
    fin = np.empty_like(t)
    done = np.zeros(len(t), bool)
    nseg = lmat.shape[0]
    for k in range(nseg):
        hi = float(breaks[k]) if k < nseg - 1 else np.inf
        l = lmat[k]
        act = ~done & (t < hi)
        cand = t + rem * l
        fdone = act & (cand <= hi)
        fin[fdone] = cand[fdone]
        done |= fdone
        part = act & ~fdone
        if part.any():
            rem[part] = np.maximum(rem[part] - (hi - t[part]) / l[part], 0.0)
            t[part] = hi
    return fin


def simulate_arrays(schedule: Schedule, telemetry: bool = False,
                    timeline=None):
    """Vectorized max-plus replay of a `vec_exact` schedule.

    Bit-identical to `simulate_reference` on eligible schedules: every start
    is the max of the same IEEE values the event loop would have observed,
    and every finish is the same single addition. ``telemetry=True``
    attaches a post-hoc `repro.obs.FlowTelemetry` (timings unchanged).

    ``timeline=`` (a `repro.core.model.FaultTimeline`) makes NIC rates
    piecewise-constant in time: the max-plus recurrence is unchanged (port
    service order is forced by the vec_exact contract, independent of
    durations), but each NIC wire flow's finish comes from
    `_segmented_finish` instead of one multiply-add. A timeline with no
    effective breakpoints degenerates to the static path bit-for-bit.
    """
    from repro.core.simulator import SimResult   # circular at module load

    fa = schedule.arrays if schedule.arrays is not None \
        else FlowArrays.from_schedule(schedule)
    n = fa.nflows
    if n == 0:
        return SimResult(0.0, {}, {}, {})
    prof = schedule.profile
    tl_breaks: tuple = ()
    if timeline is not None:
        tl_breaks, tl_vecs = timeline.segments(prof)
        sl = np.asarray(tl_vecs[0], np.float64)
    else:
        sl = np.asarray(prof.slowdown, np.float64)
    tl_on = bool(tl_breaks)
    dur = fa.size * np.maximum(sl[fa.src], sl[fa.dst])
    if fa.nv.any():
        dur[fa.nv] = fa.size[fa.nv] / prof.nvlink_rate

    # Processing order: (pri, fid) with unset pri sorting last. For all-None
    # priorities (ring) this is fid order; for slotted schedules the wire
    # flows are slot-ordered and the zero-size self-stores (pri=None, no
    # dependents) come last. The order must be topological - verified below.
    has_pri = ~np.isnan(fa.pri)
    if has_pri.any():
        key = np.where(has_pri, fa.pri, np.inf)
        order = np.lexsort((np.arange(n), key))
    else:
        order = np.arange(n)
    pos = np.empty(n, np.int64)
    pos[order] = np.arange(n)

    rel_o = fa.release[order]
    dur_o = dur[order]
    wire_o = fa.size[order] > 0
    if tl_on:
        size_o = fa.size[order]
        # [nsegs, n] effective slowdown per segment in processing order.
        src_o, dst_o = fa.src[order], fa.dst[order]
        lmax_all = np.stack([
            np.maximum(np.asarray(v, np.float64)[src_o],
                       np.asarray(v, np.float64)[dst_o])
            for v in tl_vecs])
        seg_mask = wire_o & ~fa.nv[order]   # NIC wire flows get re-timed
        breaks_arr = np.asarray(tl_breaks, np.float64)

    # Dependency CSR re-indexed to processing positions.
    counts = np.diff(fa.dep_indptr)
    counts_o = counts[order]
    indptr_o = np.zeros(n + 1, np.int64)
    np.cumsum(counts_o, out=indptr_o[1:])
    nnz = int(indptr_o[-1])
    if nnz:
        gather = (np.repeat(fa.dep_indptr[order] - indptr_o[:-1], counts_o)
                  + np.arange(nnz))
        dep_pos = pos[fa.dep_indices[gather]]
    else:
        dep_pos = np.empty(0, np.int64)

    # Port predecessor links (wire flows only; zero-size flows bypass ports).
    spred = np.full(n, -1, np.int64)
    rpred = np.full(n, -1, np.int64)
    w = np.nonzero(wire_o)[0]
    if len(w):
        src_w = fa.src[order[w]]
        dst_w = fa.dst[order[w]]
        nv_w = fa.nv[order[w]].astype(np.int64)
        _port_predecessors(w, src_w * 4 + nv_w * 2, spred)
        _port_predecessors(w, dst_w * 4 + nv_w * 2 + 1, rpred)

    # Fuse data deps and port predecessors into one in-edge CSR: start =
    # max(release, finish[in-edges]) either way, and max is associative and
    # commutative over IEEE floats (no reassociation error), so one fused
    # reduceat is bit-identical to taking the maxima separately.
    extra = (spred >= 0).astype(np.int64) + (rpred >= 0)
    ecounts = counts_o + extra
    eptr = np.zeros(n + 1, np.int64)
    np.cumsum(ecounts, out=eptr[1:])
    enz = int(eptr[-1])
    esrc = np.empty(enz, np.int64)
    if nnz:
        gat = (np.repeat(eptr[:-1] - indptr_o[:-1], counts_o)
               + np.arange(nnz))
        esrc[gat] = dep_pos
    hs = spred >= 0
    esrc[(eptr[:-1] + counts_o)[hs]] = spred[hs]
    hr = rpred >= 0
    esrc[(eptr[:-1] + counts_o + hs)[hr]] = rpred[hr]

    # Latest in-edge per flow; also the topological-order check.
    maxsrc = np.full(n, -1, np.int64)
    ne_all = ecounts > 0
    if enz:
        maxsrc[ne_all] = np.maximum.reduceat(esrc, eptr[:-1][ne_all])
    if np.any(maxsrc >= np.arange(n)):
        raise RuntimeError(
            "schedule tagged vec_exact but its flow graph is not "
            "topologically ordered by (pri, fid); cannot vectorize")

    neg = -np.inf
    finish = np.empty(n, np.float64)
    start = np.empty(n, np.float64)
    i0 = 0
    scan = 1024     # boundary-scan chunk; blocks are usually ~p flows
    while i0 < n:
        # Find the largest i1 with all in-edges of [i0, i1) before i0.
        i1 = i0 + 1
        while i1 < n:
            hi = min(i1 + scan, n)
            conflicts = np.nonzero(maxsrc[i1:hi] >= i0)[0]
            if len(conflicts):
                i1 += int(conflicts[0])
                break
            i1 = hi
        b = slice(i0, i1)
        s = rel_o[b].copy()
        lo_ptr, hi_ptr = int(eptr[i0]), int(eptr[i1])
        if hi_ptr > lo_ptr:
            vals = finish[esrc[lo_ptr:hi_ptr]]
            ne = ne_all[b]
            off = np.minimum(eptr[i0:i1] - lo_ptr, len(vals) - 1)
            edge_max = np.maximum.reduceat(vals, off)
            np.maximum(s, np.where(ne, edge_max, neg), out=s)
        start[b] = s
        fb = s + dur_o[b]
        if tl_on:
            mb = np.nonzero(seg_mask[b])[0]
            if len(mb):
                fb[mb] = _segmented_finish(s[mb], size_o[b][mb],
                                           lmax_all[:, i0:i1][:, mb],
                                           breaks_arr)
        finish[b] = fb
        i0 = i1

    makespan = float(finish.max())

    def materialize():
        start_d = dict(zip(order.tolist(), start.tolist()))
        finish_d = dict(zip(order.tolist(), finish.tolist()))
        busy: dict[tuple, float] = {}
        kinds = np.where(fa.nv[order], "nv", "nic")
        # Under a timeline the wire occupancy is the realized finish-start
        # span, not the segment-0 duration.
        eff_dur = (finish - start) if tl_on else dur_o
        for i in w.tolist():
            k = str(kinds[i])
            d = float(eff_dur[i])
            a, b_ = int(fa.src[order[i]]), int(fa.dst[order[i]])
            busy[(k, a, "s")] = busy.get((k, a, "s"), 0.0) + d
            busy[(k, b_, "r")] = busy.get((k, b_, "r"), 0.0) + d
        return start_d, finish_d, busy

    res = SimResult(makespan, lazy=materialize)
    if telemetry:
        from repro.core.simulator import _attach_telemetry
        res = _attach_telemetry(schedule, res)
    return res
