"""Named registry of AllReduce schedule generators.

Every algorithm the planner can emit is a `ScheduleAlgo` entry here:

    name         registry key, also the value of `Schedule.meta["topology"]`
                 and the `algo=` argument to `planner.make_plan`
    generate     (profile, n, k, fill_bubbles) -> Schedule (Flow objects)
    generate_arrays
                 optional vectorized twin returning a columnar
                 `FlowArrays` schedule (None -> fall back to `generate`)
    time_model   (profile, n, k) -> predicted makespan, element-time units
    lower_bound  (profile, n) -> this topology's own lower bound
                 (`core.lower_bounds`); sweeps score overhead against it
    supports     profile predicate (e.g. torus2d needs a 2-D factorization,
                 hierarchical needs gpus_per_server >= 2)
    auto         whether `make_plan(algo="auto")` may pick it. Only the
                 PR-6 pair (ring, optcc) is auto-eligible: their time
                 models are simulator-calibrated, so "auto" reproduces the
                 historical OptCC-vs-ring choice bit-for-bit. New entries
                 join "auto" once their models are calibrated the same way.
    wins_when    one-line guidance surfaced in docs/benchmarks

Use `get(name)` / `names()` / `supported(profile)`; `register` is public so
out-of-tree experiments can add entries without patching the planner.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

from repro.core import lower_bounds as lb
from repro.core.model import BandwidthProfile, Schedule


@dataclasses.dataclass(frozen=True)
class ScheduleAlgo:
    """One registered AllReduce algorithm (see module docstring)."""

    name: str
    description: str
    generate: Callable[..., Schedule]
    time_model: Callable[[BandwidthProfile, float, int], float]
    lower_bound: Callable[[BandwidthProfile, float], float]
    generate_arrays: Optional[Callable[..., Schedule]] = None
    supports: Callable[[BandwidthProfile], bool] = lambda profile: True
    auto: bool = False
    wins_when: str = ""


_REGISTRY: dict[str, ScheduleAlgo] = {}


def register(algo: ScheduleAlgo) -> ScheduleAlgo:
    if algo.name in _REGISTRY:
        raise ValueError(f"schedule algo {algo.name!r} already registered")
    _REGISTRY[algo.name] = algo
    return algo


def get(name: str) -> ScheduleAlgo:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown schedule algo {name!r}; registered: "
                         f"{', '.join(sorted(_REGISTRY))} (or 'auto')"
                         ) from None


def names() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def supported(profile: BandwidthProfile) -> tuple[str, ...]:
    return tuple(name for name in names()
                 if _REGISTRY[name].supports(profile))


def auto_candidates() -> tuple[ScheduleAlgo, ...]:
    return tuple(_REGISTRY[name] for name in names() if _REGISTRY[name].auto)


# ----------------------------------------------------------------------------
# built-in entries
# ----------------------------------------------------------------------------

def _dedup_ells(profile: BandwidthProfile) -> list[float]:
    """The planner's historical straggler normalization: with g > 1 the
    paper's construction handles exactly one degraded server, so collapse
    to the worst slowdown."""
    ells = [l for l in profile.slowdown if l > 1.0]
    if profile.gpus_per_server > 1 and ells:
        ells = [max(ells)]
    return ells


def _generic_lb(profile: BandwidthProfile, n: float) -> float:
    return lb.lower_bound(profile.p, n, _dedup_ells(profile),
                          profile.gpus_per_server)


def _ring_generate(profile, n, k=16, fill_bubbles=True):
    from repro.core.ring import ring_allreduce_schedule
    return ring_allreduce_schedule(profile, n)


def _ring_generate_arrays(profile, n, k=16, fill_bubbles=True):
    from repro.core.schedule_vec import ring_arrays
    return ring_arrays(profile, n)


def _ring_time(profile, n, k=16):
    return max(profile.slowdown) * lb.t0_fault_free(profile.p, n, 1)


def _optcc_generate(profile, n, k=16, fill_bubbles=True):
    from repro.core.schedule import optcc_schedule
    return optcc_schedule(profile, n, k, fill_bubbles)


def _optcc_generate_arrays(profile, n, k=16, fill_bubbles=True):
    from repro.core.schedule_vec import optcc_schedule_arrays
    return optcc_schedule_arrays(profile, n, k, fill_bubbles)


def _optcc_time(profile, n, k=16):
    return lb.optcc_time(profile.p, n, _dedup_ells(profile), k,
                         profile.gpus_per_server)


def _hier_generate(profile, n, k=16, fill_bubbles=True):
    from repro.core.topologies import hierarchical_schedule
    return hierarchical_schedule(profile, n, k, fill_bubbles)


def _dbtree_generate(profile, n, k=16, fill_bubbles=True):
    from repro.core.topologies import dbtree_schedule
    return dbtree_schedule(profile, n, k)


def _torus2d_generate(profile, n, k=16, fill_bubbles=True):
    from repro.core.topologies import torus2d_schedule
    return torus2d_schedule(profile, n)


def _torus2d_supports(profile: BandwidthProfile) -> bool:
    from repro.core.topologies import torus_dims
    return profile.gpus_per_server == 1 and torus_dims(profile.p) is not None


register(ScheduleAlgo(
    name="ring",
    description="FIFO bidirectional-chunk ring (Patarasuk & Yuan); the "
                "whole ring runs at the slowest NIC's rate",
    generate=_ring_generate,
    generate_arrays=_ring_generate_arrays,
    time_model=_ring_time,
    lower_bound=_generic_lb,
    supports=lambda profile: profile.p >= 2,
    auto=True,
    wins_when="healthy clusters, or stragglers so mild that OptCC's "
              "asymmetry costs more than it saves",
))

register(ScheduleAlgo(
    name="optcc",
    description="the paper's straggler-aware schedule family "
                "(single/multi-straggler and multi-GPU constructions, "
                "dispatched per profile)",
    generate=_optcc_generate,
    generate_arrays=_optcc_generate_arrays,
    time_model=_optcc_time,
    lower_bound=_generic_lb,
    supports=lambda profile: profile.p >= 2,
    auto=True,
    wins_when="one or a few degraded NICs on an otherwise healthy "
              "cluster - approaches the per-profile lower bound",
))

register(ScheduleAlgo(
    name="hierarchical",
    description="intra-server NVLink reduce + inter-server OptCC over one "
                "lead rank per server",
    generate=_hier_generate,
    time_model=lb.hierarchical_time,
    lower_bound=lb.lb_hierarchical,
    supports=lambda profile: profile.gpus_per_server >= 2,
    wins_when="multi-GPU servers with fast NVLink (nvlink_mult >> g-1): "
              "only q ranks ever touch the scarce NICs",
))

register(ScheduleAlgo(
    name="dbtree",
    description="double-binary-tree baseline (two balanced trees, each "
                "reducing+broadcasting half the vector)",
    generate=_dbtree_generate,
    time_model=lb.dbtree_time,
    lower_bound=lb.lb_dbtree,
    supports=lambda profile: profile.gpus_per_server == 1 and profile.p >= 2,
    wins_when="latency-bound regimes (tiny n, large p) - bandwidth-wise "
              "it moves ~2n per interior rank and loses to ring/optcc",
))

register(ScheduleAlgo(
    name="torus2d",
    description="2-D torus reduce (row RS, column RS, column AG, row AG) "
                "per the Google mesh paper",
    generate=_torus2d_generate,
    time_model=lb.torus2d_time,
    lower_bound=lb.lb_torus2d,
    supports=_torus2d_supports,
    wins_when="mesh/torus fabrics; bandwidth-optimal like the ring but "
              "with r- and c-length dependency chains instead of p",
))
