"""OptCC schedule construction (Section 4, Appendices C, D, E).

Closed-form O(p k) generation, no solver - suitable for online re-planning
(the paper reports < 1 ms at p=1024; see benchmarks/schedule_gen_speed.py).

Three generators:
  * optcc_single_schedule     - one straggler, one GPU/server (Sec 4.1-4.3),
                                with Appendix-C bubble filling for l < 2;
  * optcc_multi_schedule      - m stragglers, one GPU/server (Appendix D);
  * optcc_multi_gpu_schedule  - one straggler server, g GPUs/server (App E),
                                with NVLink N-phases around every NIC stage.

Stage orderings (Section 4.1): segments alternate between
  ordering A:  S1 -> S2 -> S3 -> S4   (healthy reduce-scatter first, straggler
               receives the healthy partial sum, folds its own, sends back)
  ordering B:  S3 -> S1 -> S4 -> S2   (straggler uploads its raw contribution
               first; the healthy ring folds it during reduce-scatter; the
               result returns to the straggler last)
Patterns C/D are A/B with rotated section ownership (the paper's offset);
rotation happens implicitly through per-segment owner rotation here.

The simulator's port-exclusive, priority-ordered greedy dispatch turns these
dependency graphs into the paper's pipelined timeline; fids encode schedule
priority (segment-major). Timing is validated against Eq. (1)/(2), D.3 and
E.4 in tests/test_schedule_time.py; data correctness in
tests/test_schedule_correctness.py via core.executor.
"""
from __future__ import annotations

import numpy as np

from repro.core.model import STAGE_ID, BandwidthProfile, Flow, Op, Schedule
from repro.core.ring import ring_allreduce_schedule, split_points


# ----------------------------------------------------------------------------
# helpers
# ----------------------------------------------------------------------------

class _FlowList:
    """Flow accumulator handing out monotonically increasing fids.

    Every flow carries a pipeline-stage tag (model.STAGE_NAMES) recorded in
    fid order; the finished array lands in ``Schedule.meta["stage_ids"]``
    for the observability layer. Tags are metadata only - the simulator's
    timing paths never read them.
    """

    def __init__(self):
        self.nic: list[Flow] = []
        self.nv: list[Flow] = []
        self.stages: list[int] = []

    def add(self, src, dst, size, deps, lo, hi, op, key, nvlink=False,
            pri=None, extra=(), stage="SELF") -> int:
        fid = len(self.nic) + len(self.nv)
        f = Flow(fid=fid, src=src, dst=dst, size=float(size),
                 deps=tuple(deps), lo=lo, hi=hi, op=op, key=key, pri=pri,
                 extra=tuple(extra))
        (self.nv if nvlink else self.nic).append(f)
        self.stages.append(STAGE_ID[stage])
        return fid

    def stage_ids(self) -> np.ndarray:
        return np.asarray(self.stages, np.int16)


def _ring_chain(fl: _FlowList, nodes: list[int], lo: int, hi: int, key: tuple,
                first_deps=(), per_node_deps=None, pri0=None, pri_step=0.0,
                nvlink=False, stage="S1") -> int:
    """ACCUM chain nodes[0] -> nodes[1] -> ... -> nodes[-1]; returns last fid.

    per_node_deps: optional {node_rank: [extra fids]} added to the *outgoing*
    flow of that node (used to fold straggler uploads / NVLink collects in
    before a node forwards). pri0/pri_step: slotted priorities per hop.
    stage: one tag for every hop, or a per-hop sequence (ordering-B chains
    start with the straggler's S3 upload, then continue as S1 hops).
    """
    last = None
    per_hop = not isinstance(stage, str)
    for t, (a, b) in enumerate(zip(nodes[:-1], nodes[1:])):
        deps = list(first_deps) if last is None else [last]
        if per_node_deps:
            deps.extend(per_node_deps.get(a, ()))
        pri = None if pri0 is None else pri0 + t * pri_step
        last = fl.add(a, b, hi - lo, deps, lo, hi, Op.ACCUM, key, pri=pri,
                      nvlink=nvlink, stage=stage[t] if per_hop else stage)
    return last


def _store_chain(fl: _FlowList, nodes: list[int], lo: int, hi: int, key: tuple,
                 first_deps=(), pri0=None, pri_step=0.0,
                 nvlink=False, stage="S4") -> list[int]:
    """STORE forward chain; returns fids (one per hop)."""
    fids, last = [], None
    for t, (a, b) in enumerate(zip(nodes[:-1], nodes[1:])):
        deps = list(first_deps) if last is None else [last]
        pri = None if pri0 is None else pri0 + t * pri_step
        last = fl.add(a, b, hi - lo, deps, lo, hi, Op.STORE, key, pri=pri,
                      nvlink=nvlink, stage=stage)
        fids.append(last)
    return fids


# ----------------------------------------------------------------------------
# single straggler, one GPU per server (Section 4)
# ----------------------------------------------------------------------------

def optcc_single_schedule(profile: BandwidthProfile, n: int, k: int,
                          fill_bubbles: bool = True,
                          alternate_orderings: bool = False,
                          slot_release: bool = True) -> Schedule:
    """Single straggler, one GPU/server.

    Default path (`_optcc_single_slotted`): an exact, provably collision-free
    slotted construction equivalent to the paper's four-pattern overlay
    (Figures 5-7). In units of the ideal section size s' and with ph = p-1:

      * S1 (reduce-scatter) of segment m: section j's chain staggered to
        start at offset 2j of body m, hop t at offset 2j+t; sender of hop t
        is healthy[(j+m+1+t) mod ph].
      * S2 of segment m (merged with the Appendix-C star-upload when l<2,
        so the wire flow lasts exactly one 2s' slot): offset
        ((2j+ph-4) mod 2ph) of body m+1.
      * S3 (merged with the star-download): offset ((2j+ph-6) mod 2ph) of
        body m+2.
      * S4 (allgather): section j's chain starts at offset
        ((2j+ph-9) mod 2ph) of body m+3, sender of hop t is
        healthy[(j+m+t) mod ph].

    For every healthy send port with phase g = (rank_index - body) mod ph,
    the S1 cells {2j + ((g-1-j) mod ph)}, the S4 cells
    {2j+ph-9 + ((g+3-j) mod ph)} and the 2-cell straggler window
    [2g+ph-2, 2g+ph) tile the body circle [0, 2ph) exactly (verified for
    all ph in tests); receive ports tile by the shift symmetry
    recv(port a) = send(port a-1). Hence zero steady-state bubbles - the
    schedule achieves Eq. (1)/(2) up to the 4-body pipeline head/tail.

    With `alternate_orderings=True` (or ph < 4), the legacy generator is
    used: segments alternate the paper's ordering A (S1-S2-S3-S4) and
    ordering B (S3-S1-S4-S2); correct and pattern-faithful but relies on
    greedy dispatch, so it carries a few percent of scheduling slack.
    """
    if alternate_orderings or profile.p - 1 < 4:
        return _optcc_single_legacy(profile, n, k, fill_bubbles,
                                    alternate_orderings)
    return _optcc_single_slotted(profile, n, k, fill_bubbles, slot_release)


def _optcc_single_slotted(profile: BandwidthProfile, n: int, k: int,
                          fill_bubbles: bool, slot_release: bool) -> Schedule:
    """Exact zero-bubble construction (see optcc_single_schedule docstring).

    All times in units of the ideal section size s'; body B = w*ph with
    w = max(l, 2). Everything is keyed on the *owner index*
    nu = (j + m) mod ph, which makes each port's per-body occupancy pattern
    independent of the segment index m - the property that lets per-body
    cell sets tile exactly (cells spilling into the next body are replaced
    by the previous segment's identical pattern):

      port alpha send:  S1 cells [2a, 2a+ph-2] | S4 [2a+ph-1, 2a+2ph-3]
                        | S2 window [2a+2ph-2, 2a+2ph-1]   (a = 2*alpha)
      port alpha recv:  shift symmetry recv(alpha) = send(alpha-1)
      straggler recv:   S2 slots {2nu-2 mod 2ph}  (tile)
      straggler send:   S3 slots {2nu-4 mod 2ph}  (tile)

    For l < 2, S2/S3 are *enlarged* (Appendix C) with the star-block chunk
    so each wire flow lasts exactly one 2-cell slot; S2(m) uploads star
    block m, S3(m) returns star block m-1 (k-1 blocks total).
    """
    import dataclasses

    p = profile.p
    (s_rank,) = profile.stragglers
    ell = profile.slowdown[s_rank]
    healthy = [r for r in range(p) if r != s_rank]
    ph = p - 1

    fill = fill_bubbles and ell < 2.0 and k >= 2
    if fill:
        ring_frac = ell * ph / ((p - 2) * ell + 2.0)
        ring_n = int(round(n * ring_frac))
    else:
        ring_n = n
    seg_bounds = split_points(ring_n, k)
    # k-1 star blocks: block m is uploaded with segment m's S2 flows and
    # downloaded with segment m+1's S3 flows.
    star_bounds = split_points(n - ring_n, max(k - 1, 1)) + ring_n
    s_i = ring_n / (k * ph) if ring_n else 1.0
    w = max(ell, 2.0)
    B = w * ph * s_i

    def slot2(m, nu):   # S2 upload slot (straggler recv)
        if ell <= 2.0:
            return ((m + 1) * B + (2 * nu + 2 * ph - 2) * s_i)
        return (m + 1) * B + ell * nu * s_i

    def slot3(m, nu):   # S3 download slot (straggler send)
        if ell <= 2.0:
            return ((m + 2) * B + (2 * nu + 2 * ph - 4) * s_i)
        return (m + 2) * B + ell * nu * s_i

    fl = _FlowList()
    prev_ups: list[int] = []
    prev_block: tuple[int, int] = (0, 0)
    for m in range(k):
        sec_bounds = split_points(int(seg_bounds[m + 1] - seg_bounds[m]), ph) \
            + int(seg_bounds[m])
        if fill and m < k - 1:
            blo, bhi = int(star_bounds[m]), int(star_bounds[m + 1])
        else:
            blo = bhi = 0
        c = bhi - blo
        # Pass 1: S1 chains + merged S2 uploads (star block m).
        s1_of: list = [None] * ph
        s2_of: list = [None] * ph
        for j in range(ph):
            lo, hi = int(sec_bounds[j]), int(sec_bounds[j + 1])
            if hi <= lo:
                continue
            key = ("sec", m, j)
            nu = (j + m) % ph
            owner = healthy[nu]
            chain = [healthy[(nu + 1 + t) % ph] for t in range(ph)]
            s1_of[j] = _ring_chain(fl, chain, lo, hi, key,
                                   pri0=m * B + (2 * nu + ph) * s_i,
                                   pri_step=s_i)
            extra = ((blo, bhi, Op.ACCUM, ("star", m)),) if c > 0 else ()
            s2_of[j] = fl.add(owner, s_rank, (hi - lo) + c, [s1_of[j]],
                              lo, hi, Op.ACCUM, key,
                              pri=slot2(m, nu), extra=extra, stage="S2")
        ups = [f for f in s2_of if f is not None]
        if c > 0 and ups:
            # straggler's own star-block output (local, zero wire time).
            fl.add(s_rank, s_rank, 0.0, ups, blo, bhi, Op.STORE, ("star", m))
        # Pass 2: merged S3 downloads (star block m-1) + S4 + self-stores.
        pblo, pbhi = prev_block
        pc = pbhi - pblo
        for j in range(ph):
            if s2_of[j] is None:
                continue
            lo, hi = int(sec_bounds[j]), int(sec_bounds[j + 1])
            key = ("sec", m, j)
            nu = (j + m) % ph
            owner = healthy[nu]
            extra = ((pblo, pbhi, Op.STORE, ("star", m - 1)),) if pc else ()
            deps3 = [s2_of[j]] + (prev_ups if pc else [])
            s3 = fl.add(s_rank, owner, (hi - lo) + pc, deps3, lo, hi,
                        Op.STORE, key, pri=slot3(m, nu), extra=extra,
                        stage="S3")
            # straggler's own section output.
            fl.add(s_rank, s_rank, 0.0, [s2_of[j]], lo, hi, Op.STORE, key)
            ag = [healthy[(nu + t) % ph] for t in range(ph)]
            _store_chain(fl, ag, lo, hi, key, first_deps=[s3],
                         pri0=(m + 3) * B + (2 * nu + 2 * ph - 3) * s_i,
                         pri_step=s_i)
        prev_ups, prev_block = ups, (blo, bhi)

    # Tail: the last star block (k-2) was returned by segment k-1's S3;
    # all blocks are closed. (Block indices run 0..k-2.)
    flows = fl.nic
    if slot_release:
        flows = [dataclasses.replace(f, release=(f.pri or 0.0))
                 for f in flows]
    meta = {"algo": "optcc-single", "topology": "optcc", "k": k, "ell": ell,
            "fill": fill, "slotted": True, "stage_ids": fl.stage_ids()}
    # For l <= 2 the body tiling is exactly collision-free, so forcing every
    # port to serve its flows strictly in (pri, fid) order (port_inorder: a
    # NIC draining its transmit queue in schedule order, what a real proxy
    # thread does) costs nothing and makes completion times an exact
    # max-plus recurrence, hence vec_exact (core.flowvec). For l > 2 the
    # slot layout keeps its w=2 Stage-1/4 offsets inside a longer l*ph body;
    # those offsets are *not* service-order-feasible (Stage-4 drips collide
    # with later segments' Stage-1 chains), so in-order service adds a
    # per-segment convoy penalty. Greedy dispatch absorbs those collisions,
    # so l > 2 keeps opportunistic semantics and the optimized greedy loop.
    if ell <= 2:
        meta["port_inorder"] = True
        meta["vec_exact"] = True
    return Schedule(profile=profile, n=n, nic_flows=flows, meta=meta)


def _optcc_single_legacy(profile: BandwidthProfile, n: int, k: int,
                         fill_bubbles: bool = True,
                         alternate_orderings: bool = True) -> Schedule:
    p = profile.p
    (s_rank,) = profile.stragglers
    ell = profile.slowdown[s_rank]
    if p < 3:
        raise ValueError("OptCC requires p >= 3")
    healthy = [r for r in range(p) if r != s_rank]
    ph = p - 1

    fill = fill_bubbles and ell < 2.0
    if fill:
        # Appendix C: ring path gets fraction l(p-1)/((p-2)l+2) of the data,
        # the star (bubble) path the rest; both split into k bodies.
        ring_frac = ell * ph / ((p - 2) * ell + 2.0)
        ring_n = int(round(n * ring_frac))
    else:
        ring_n = n
    seg_bounds = split_points(ring_n, k)
    star_bounds = split_points(n - ring_n, k) + ring_n  # [ring_n, n)

    # Slotted-timeline constants (ideal, real sizes are integer-rounded).
    s_ideal = ring_n / (k * ph)
    slot_w = max(ell, 2.0) * s_ideal          # straggler slot width
    body = ph * slot_w                        # parallel-body duration

    fl = _FlowList()
    prev_star_up: list[int] = []

    for m in range(k):
        sec_bounds = split_points(int(seg_bounds[m + 1] - seg_bounds[m]), ph) \
            + int(seg_bounds[m])
        ordering_a = (m % 2 == 0) or not alternate_orderings
        t_s1 = m * body                       # S1 rounds: body m
        t_s23 = (m + 1) * body                # S2/S3 slots: body m+1
        t_s4 = (m + 2) * body + (p - 2) * s_ideal   # S4 rounds: body m+2
        for j in range(ph):
            lo, hi = int(sec_bounds[j]), int(sec_bounds[j + 1])
            if hi <= lo:
                continue
            key = ("sec", m, j)
            oidx = (j + m) % ph      # owner rotation = pattern offset
            owner = healthy[oidx]
            if ordering_a:
                # S1: reduce-scatter ending at owner (p-1 nodes, p-2 hops).
                chain = [healthy[(oidx + 1 + t) % ph] for t in range(ph)]
                assert chain[-1] == owner
                s1 = _ring_chain(fl, chain, lo, hi, key,
                                 pri0=t_s1, pri_step=s_ideal)
                # S2: owner uploads healthy partial; straggler folds own.
                s2 = fl.add(owner, s_rank, hi - lo, [s1], lo, hi,
                            Op.ACCUM, key, pri=t_s23 + j * slot_w,
                            stage="S2")
                # S3: straggler downloads global sum to owner.
                s3 = fl.add(s_rank, owner, hi - lo, [s2], lo, hi,
                            Op.STORE, key,
                            pri=t_s23 + j * slot_w + ell * s_ideal,
                            stage="S3")
                # straggler's own output (zero-cost self store).
                fl.add(s_rank, s_rank, 0.0, [s2], lo, hi, Op.STORE, key)
                # S4: allgather among healthy from owner.
                ag = [healthy[(oidx + t) % ph] for t in range(ph)]
                _store_chain(fl, ag, lo, hi, key, first_deps=[s3],
                             pri0=t_s4, pri_step=s_ideal)
            else:
                # S3': straggler uploads raw first; entry node starts ring.
                entry_idx = (j + m) % ph
                chain = [s_rank] + [healthy[(entry_idx + t) % ph]
                                    for t in range(ph)]
                owner = chain[-1]
                # First hop is the straggler's raw upload (S3 in the paper's
                # ordering-B naming); the rest is the healthy ring (S1).
                s1 = _ring_chain(fl, chain, lo, hi, key,
                                 stage=["S3"] + ["S1"] * (len(chain) - 2))
                # owner's own output.
                fl.add(owner, owner, 0.0, [s1], lo, hi, Op.STORE, key)
                # S4: allgather among healthy from owner.
                ag = [healthy[(entry_idx + ph - 1 + t) % ph]
                      for t in range(ph)]
                assert ag[0] == owner
                ag_fids = _store_chain(fl, ag, lo, hi, key, first_deps=[s1])
                # S2': the last allgather receiver returns the global sum.
                fl.add(ag[-1], s_rank, hi - lo, [ag_fids[-1]], lo, hi,
                       Op.STORE, key, stage="S2")

        if fill:
            # Appendix C star all-reduce in the straggler-link bubbles:
            # body m uploads (in the bubble after each S2 recv slot),
            # body m+1 downloads (after each S3 send slot).
            blo, bhi = int(star_bounds[m]), int(star_bounds[m + 1])
            ups: list[int] = []
            if bhi > blo:
                skey = ("star", m)
                for j, h in enumerate(healthy):
                    ups.append(fl.add(
                        h, s_rank, bhi - blo, [], blo, bhi, Op.ACCUM, skey,
                        pri=m * body + j * slot_w + ell * s_ideal,
                        stage="STAR"))
                fl.add(s_rank, s_rank, 0.0, ups, blo, bhi, Op.STORE, skey)
            if prev_star_up:
                pm = m - 1
                plo, phi_ = int(star_bounds[pm]), int(star_bounds[pm + 1])
                for j, h in enumerate(healthy):
                    fl.add(s_rank, h, phi_ - plo, prev_star_up,
                           plo, phi_, Op.STORE, ("star", pm),
                           pri=m * body + j * slot_w + 2 * ell * s_ideal,
                           stage="STAR")
            prev_star_up = ups

    if fill and prev_star_up:
        pm = k - 1
        plo, phi_ = int(star_bounds[pm]), int(star_bounds[pm + 1])
        for j, h in enumerate(healthy):
            fl.add(s_rank, h, phi_ - plo, prev_star_up,
                   plo, phi_, Op.STORE, ("star", pm),
                   pri=(k) * body + j * slot_w + 2 * ell * s_ideal,
                   stage="STAR")

    return Schedule(profile=profile, n=n, nic_flows=fl.nic,
                    meta={"algo": "optcc-single", "topology": "optcc",
                          "k": k, "ell": ell, "fill": fill,
                          "stage_ids": fl.stage_ids()})


# ----------------------------------------------------------------------------
# m stragglers, one GPU per server (Appendix D)
# ----------------------------------------------------------------------------

def optcc_multi_schedule(profile: BandwidthProfile, n: int, k: int) -> Schedule:
    """Ordering-B-flavoured multi-straggler schedule.

    Stragglers upload their raw sections first; uploads are spread over
    distinct ring nodes (one per straggler) so no single healthy recv port
    concentrates all m uploads. Downloads are likewise spread over distinct
    allgather receivers. Cost structure matches Appendix D.3:
    each straggler i sends/receives (p-m) sections per segment at l_i each.
    """
    p = profile.p
    stragglers = list(profile.stragglers)
    m = len(stragglers)
    healthy = [r for r in range(p) if r not in set(stragglers)]
    ph = p - m
    if ph < 2:
        raise ValueError("need at least 2 healthy GPUs")

    seg_bounds = split_points(n, k)
    fl = _FlowList()

    for seg in range(k):
        sec_bounds = split_points(int(seg_bounds[seg + 1] - seg_bounds[seg]),
                                  ph) + int(seg_bounds[seg])
        for j in range(ph):
            lo, hi = int(sec_bounds[j]), int(sec_bounds[j + 1])
            if hi <= lo:
                continue
            key = ("sec", seg, j)
            oidx = (j + seg) % ph
            # Ring chain covering all healthy, ending at the owner.
            chain = [healthy[(oidx + 1 + t) % ph] for t in range(ph)]
            owner = chain[-1]
            # Straggler i uploads its raw section to the (i+1)-th chain node;
            # that node folds the raw into its buffer before forwarding.
            per_node_deps: dict[int, list[int]] = {}
            ups = []
            for i, srank in enumerate(stragglers):
                tgt = chain[i % ph]
                up = fl.add(srank, tgt, hi - lo, [], lo, hi, Op.ACCUM, key,
                            stage="S3")
                per_node_deps.setdefault(tgt, []).append(up)
                ups.append(up)
            last = _ring_chain(fl, chain, lo, hi, key,
                               per_node_deps=per_node_deps)
            # Owner might hold straggler uploads targeted at itself that the
            # chain didn't wait for; the global sum exists only after both.
            ready = [last] + per_node_deps.get(owner, [])
            # owner's own output.
            fl.add(owner, owner, 0.0, ready, lo, hi, Op.STORE, key)
            # Allgather among healthy from owner.
            ag = [healthy[(oidx + t) % ph] for t in range(ph)]
            assert ag[0] == owner
            ag_fids = _store_chain(fl, ag, lo, hi, key, first_deps=ready)
            # Downloads: the t-th allgather receiver returns the global sum
            # to straggler t (spread across ports).
            for i, srank in enumerate(stragglers):
                node_pos = 1 + (i % (ph - 1))
                sender = ag[node_pos]
                fl.add(sender, srank, hi - lo, [ag_fids[node_pos - 1]],
                       lo, hi, Op.STORE, key, stage="S2")

    return Schedule(profile=profile, n=n, nic_flows=fl.nic,
                    meta={"algo": "optcc-multi", "topology": "optcc",
                          "k": k, "m": m, "stage_ids": fl.stage_ids()})


# ----------------------------------------------------------------------------
# one straggler server, g GPUs per server (Appendix E)
# ----------------------------------------------------------------------------

def optcc_multi_gpu_schedule(profile: BandwidthProfile, n: int, k: int) -> Schedule:
    """g concurrent lead cycles (one per local GPU index) over q servers,
    each running the single-straggler NIC schedule on its n/g slice, plus
    NVLink collect (N1/N3) before sends and distribute (N2/N4) after
    receives. NVLink ports run at (g-1)x NIC rate (paper's provisioning).
    """
    p, g = profile.p, profile.gpus_per_server
    q = p // g
    if q < 3:
        raise ValueError("need q >= 3 servers")
    # Identify the straggler server.
    sserver = None
    for j in range(q):
        if profile.slowdown[j * g] > 1.0:
            sserver = j
    assert sserver is not None, "no straggler server in profile"
    ell = profile.slowdown[sserver * g]
    healthy_srv = [j for j in range(q) if j != sserver]
    qh = q - 1

    part_bounds = split_points(n, g)
    fl = _FlowList()

    def locals_of(server: int, lead_pos: int) -> list[int]:
        """Server's ranks ordered so the lead is last (collect chain order)."""
        ranks = [server * g + r for r in range(g)]
        lead = server * g + lead_pos
        rest = [r for r in ranks if r != lead]
        return rest + [lead]

    for cyc in range(g):
        c_lo = int(part_bounds[cyc])
        c_n = int(part_bounds[cyc + 1]) - c_lo
        lead = {j: j * g + cyc for j in range(q)}
        s_lead = lead[sserver]
        seg_bounds = split_points(c_n, k) + c_lo
        for seg in range(k):
            sec_bounds = split_points(
                int(seg_bounds[seg + 1] - seg_bounds[seg]), qh) \
                + int(seg_bounds[seg])
            ordering_a = (seg % 2 == 0)
            for j in range(qh):
                lo, hi = int(sec_bounds[j]), int(sec_bounds[j + 1])
                if hi <= lo:
                    continue
                key = ("sec", cyc, seg, j)
                oidx = (j + seg) % qh

                # N1 collect at every healthy server (fold local GPUs into
                # the lead's buffer for this key). Straggler server collect
                # (N3) likewise; all raw-started, order-independent ACCUMs.
                n1_last: dict[int, int] = {}
                for srv in range(q):
                    ch = locals_of(srv, cyc)
                    if g > 1:
                        n1_last[srv] = _ring_chain(
                            fl, ch, lo, hi, key, first_deps=(), nvlink=True,
                            stage="N3" if srv == sserver else "N1")
                per_node_deps = {lead[srv]: [n1_last[srv]]
                                 for srv in n1_last}

                if ordering_a:
                    srv_chain = [healthy_srv[(oidx + 1 + t) % qh]
                                 for t in range(qh)]
                    owner_srv = srv_chain[-1]
                    chain = [lead[srv] for srv in srv_chain]
                    s1 = _ring_chain(fl, chain, lo, hi, key,
                                     per_node_deps=per_node_deps)
                    up_deps = [s1] + per_node_deps.get(chain[-1], [])
                    s2 = fl.add(chain[-1], s_lead, hi - lo, up_deps,
                                lo, hi, Op.ACCUM, key, stage="S2")
                    # straggler lead now needs its *local* collect too before
                    # the download carries the true global sum.
                    down_deps = [s2] + per_node_deps.get(s_lead, [])
                    s3 = fl.add(s_lead, chain[-1], hi - lo, down_deps,
                                lo, hi, Op.STORE, key, stage="S3")
                    fl.add(s_lead, s_lead, 0.0, down_deps, lo, hi,
                           Op.STORE, key)
                    # N2 distribute on the straggler server.
                    if g > 1:
                        _store_chain(fl, locals_of(sserver, cyc)[::-1],
                                     lo, hi, key, first_deps=down_deps,
                                     nvlink=True, stage="N2")
                    ag_srv = [healthy_srv[(oidx + t) % qh] for t in range(qh)]
                    assert ag_srv[0] == owner_srv
                    ag = [lead[srv] for srv in ag_srv]
                    ag_fids = _store_chain(fl, ag, lo, hi, key,
                                           first_deps=[s3])
                    # N4 distribute at every healthy server.
                    if g > 1:
                        _store_chain(fl, locals_of(owner_srv, cyc)[::-1],
                                     lo, hi, key, first_deps=[s3],
                                     nvlink=True, stage="N4")
                        for t in range(1, qh):
                            _store_chain(fl, locals_of(ag_srv[t], cyc)[::-1],
                                         lo, hi, key,
                                         first_deps=[ag_fids[t - 1]],
                                         nvlink=True, stage="N4")
                else:
                    entry_idx = (j + seg) % qh
                    srv_chain = [healthy_srv[(entry_idx + t) % qh]
                                 for t in range(qh)]
                    chain = [s_lead] + [lead[srv] for srv in srv_chain]
                    owner_srv = srv_chain[-1]
                    # Straggler raw upload must carry its full server-local
                    # sum: fold its collect in first.
                    pnd = dict(per_node_deps)
                    pnd.setdefault(s_lead, [])
                    s1 = _ring_chain(fl, chain, lo, hi, key,
                                     per_node_deps=pnd,
                                     stage=["S3"] + ["S1"] * (len(chain) - 2))
                    own_deps = [s1] + per_node_deps.get(chain[-1], [])
                    fl.add(chain[-1], chain[-1], 0.0, own_deps, lo, hi,
                           Op.STORE, key)
                    ag_srv = [healthy_srv[(entry_idx + qh - 1 + t) % qh]
                              for t in range(qh)]
                    assert ag_srv[0] == owner_srv
                    ag = [lead[srv] for srv in ag_srv]
                    ag_fids = _store_chain(fl, ag, lo, hi, key,
                                           first_deps=own_deps)
                    s2p = fl.add(ag[-1], s_lead, hi - lo, [ag_fids[-1]],
                                 lo, hi, Op.STORE, key, stage="S2")
                    if g > 1:
                        # N4 at healthy servers.
                        _store_chain(fl, locals_of(owner_srv, cyc)[::-1],
                                     lo, hi, key, first_deps=own_deps,
                                     nvlink=True, stage="N4")
                        for t in range(1, qh):
                            _store_chain(fl, locals_of(ag_srv[t], cyc)[::-1],
                                         lo, hi, key,
                                         first_deps=[ag_fids[t - 1]],
                                         nvlink=True, stage="N4")
                        # N2 on the straggler server after the final return.
                        _store_chain(fl, locals_of(sserver, cyc)[::-1],
                                     lo, hi, key, first_deps=[s2p],
                                     nvlink=True, stage="N2")

    return Schedule(profile=profile, n=n, nic_flows=fl.nic,
                    nvlink_flows=fl.nv,
                    meta={"algo": "optcc-multigpu", "topology": "optcc",
                          "k": k, "g": g, "ell": ell,
                          "stage_ids": fl.stage_ids()})


# ----------------------------------------------------------------------------
# dispatcher
# ----------------------------------------------------------------------------

def optcc_schedule(profile: BandwidthProfile, n: int, k: int = 16,
                   fill_bubbles: bool = True) -> Schedule:
    """Build the OptCC schedule appropriate for a bandwidth profile."""
    stragglers = profile.stragglers
    if profile.gpus_per_server > 1:
        if not stragglers:
            return ring_allreduce_schedule(profile, n)
        return optcc_multi_gpu_schedule(profile, n, k)
    if not stragglers:
        return ring_allreduce_schedule(profile, n)
    if len(stragglers) == 1:
        return optcc_single_schedule(profile, n, k, fill_bubbles)
    return optcc_multi_schedule(profile, n, k)
