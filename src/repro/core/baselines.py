"""Baselines from the paper's evaluation (Section 5).

  * nccl_no_failure : ring AllReduce on the healthy topology (T -> T0).
  * iccl            : ring AllReduce resumed unchanged on the degraded
                      topology [1] - simulate ring on the degraded profile.
  * r2ccl           : state-of-the-art NIC-fault-tolerant AllReduce [30];
                      the paper gives its closed form (Fig. 20 caption):
                      T = T_NCCL_optimal * (1 + p (l-1) / (2 (p-1))).
"""
from __future__ import annotations

from repro.core.lower_bounds import t0_fault_free
from repro.core.model import BandwidthProfile
from repro.core.ring import ring_allreduce_schedule
from repro.core.simulator import simulate


def nccl_no_failure_time(p: int, n: float, g: int = 1) -> float:
    return t0_fault_free(p, n, g)


def iccl_time_asymptotic(p: int, n: float, ell: float, g: int = 1) -> float:
    """Degraded ring: the straggler's port carries the full per-rank volume
    at rate 1/l, throttling every round: T -> l * T0."""
    return ell * t0_fault_free(p, n, g)


def iccl_time_simulated(profile: BandwidthProfile, n: int) -> float:
    return simulate(ring_allreduce_schedule(profile, n)).makespan


def r2ccl_time(p: int, n: float, ell: float, g: int = 1) -> float:
    """Closed form reported by the paper for R2CCL."""
    return t0_fault_free(p, n, g) * (1.0 + p * (ell - 1.0) / (2.0 * (p - 1)))
