"""Ring AllReduce flow schedule (Patarasuk-Yuan / NCCL ring).

Used two ways:
  * on a healthy BandwidthProfile -> NCCL_NoFailure baseline (T -> T0);
  * on a degraded profile        -> ICCL baseline: the liveness-oriented
    systems of Section 2 resume the *unchanged* ring after failover, so the
    straggler's slow NIC stays on every chunk's critical path and throttles
    the whole collective (T -> l * T0 in the clean flow model; the paper
    measures even worse under PXN pool congestion, which our single-port
    model does not add on top).

Construction: vector split into p chunks. Reduce-scatter: p-1 rounds, in
round t rank r sends chunk (r - t) mod p to rank (r+1) mod p (ACCUM).
Allgather: p-1 rounds, in round t rank r sends chunk (r + 1 - t) mod p
(STORE). Dependencies follow each chunk's reduction chain, so rounds
pipeline naturally in the simulator.

Each rank additionally sends its rounds *in order* (a FIFO dependency on
its own previous send), modelling a real NCCL ring where a rank's proxy
thread posts sends in ring order. Without this, near-even chunk rounding
lets the greedy simulator reorder sends at mild slowdowns, and the resulting
convoy effect made degraded-ring time non-monotonic in ell (PR-5 follow-up).
With FIFO sends the ring is a contention-free max-plus system: every flow
starts exactly at max(finish[deps]), which is (a) provably monotone in every
slowdown factor and (b) what lets `core.flowvec` replay the ring as a
vectorized recurrence, bit-identical to the event loop
(meta["vec_exact"]).
"""
from __future__ import annotations

import numpy as np

from repro.core.model import STAGE_ID, BandwidthProfile, Flow, Op, Schedule


def split_points(n: int, parts: int) -> np.ndarray:
    """parts+1 integer boundaries splitting [0, n) near-evenly."""
    return np.round(np.linspace(0, n, parts + 1)).astype(np.int64)


def ring_allreduce_schedule(profile: BandwidthProfile, n: int) -> Schedule:
    p = profile.p
    if p < 2:
        raise ValueError("need p >= 2")
    bounds = split_points(n, p)
    flows: list[Flow] = []
    fid = 0
    # last_flow[(r, c)] = fid of the flow that most recently delivered chunk c
    # to rank r (the dependency for r's next send of chunk c).
    last_recv: dict[tuple[int, int], int] = {}
    # last_send[r] = fid of rank r's previous wire send (FIFO sequencing).
    last_send: dict[int, int] = {}

    def fifo(r: int, deps: tuple[int, ...]) -> tuple[int, ...]:
        prev = last_send.get(r)
        if prev is not None and prev not in deps:
            deps = deps + (prev,)
        return deps

    # Reduce-scatter.
    for t in range(p - 1):
        for r in range(p):
            c = (r - t) % p
            dst = (r + 1) % p
            deps = ()
            if t > 0:
                deps = (last_recv[(r, c)],)
            lo, hi = int(bounds[c]), int(bounds[c + 1])
            flows.append(Flow(fid=fid, src=r, dst=dst, size=hi - lo,
                              deps=fifo(r, deps), lo=lo, hi=hi, op=Op.ACCUM,
                              key=("rs", c)))
            last_recv[(dst, c)] = fid
            last_send[r] = fid
            fid += 1

    # After RS, rank r holds the full sum of chunk (r + 1) mod p. Self-store
    # (zero-cost src==dst flow) so out[] is complete at the owner too.
    for r in range(p):
        c = (r + 1) % p
        lo, hi = int(bounds[c]), int(bounds[c + 1])
        flows.append(Flow(fid=fid, src=r, dst=r, size=0.0,
                          deps=(last_recv[(r, c)],), lo=lo, hi=hi,
                          op=Op.STORE, key=("rs", c)))
        last_recv[(r, c)] = fid
        fid += 1

    # Allgather.
    for t in range(p - 1):
        for r in range(p):
            c = (r + 1 - t) % p
            dst = (r + 1) % p
            deps = (last_recv[(r, c)],)
            lo, hi = int(bounds[c]), int(bounds[c + 1])
            flows.append(Flow(fid=fid, src=r, dst=dst, size=hi - lo,
                              deps=fifo(r, deps), lo=lo, hi=hi, op=Op.STORE,
                              key=("rs", c)))
            last_recv[(dst, c)] = fid
            last_send[r] = fid
            fid += 1

    # Stage tags by fid-block: (p-1)*p RS rounds, p self-stores, (p-1)*p AG.
    stage_ids = np.empty(len(flows), np.int16)
    stage_ids[:(p - 1) * p] = STAGE_ID["RS"]
    stage_ids[(p - 1) * p:p * p] = STAGE_ID["SELF"]
    stage_ids[p * p:] = STAGE_ID["AG"]
    return Schedule(profile=profile, n=n, nic_flows=flows,
                    meta={"algo": "ring", "topology": "ring", "p": p,
                          "vec_exact": True, "stage_ids": stage_ids})
