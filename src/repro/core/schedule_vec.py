"""Vectorized (array-program) schedule generators for the sweep hot path.

These build the *same* flow graphs as `core.ring` / `core.schedule` - same
fids, sources, destinations, sizes, dependencies, priorities and releases -
but as columnar `core.flowvec.FlowArrays` instead of per-flow `Flow`
objects. Constructing a Flow dataclass costs ~10us; at sweep scale (10^5-10^6
flows per scenario) object construction dominates schedule generation, so
the hot path never materializes flows at all: the returned `Schedule` has
empty `nic_flows` and `schedule.arrays` set, which both simulator fast paths
consume directly.

Bit-equality with the scalar generators is enforced by
tests/test_vectorized_equivalence.py: `FlowArrays.from_schedule(scalar)`
must equal the arrays built here, field for field. Section sizes come from
the same `split_points` calls (one per segment - a k-iteration loop, not a
hot path) so integer rounding is identical; priority/release arithmetic
follows the scalar expressions' exact association, so the floats are
identical too.

The generators fall back to the scalar path for the shapes it special-cases
(ph < 4 legacy ordering, empty sections from extreme rounding): the
returned schedule is then Flow-based and the simulator converts on demand.
Semantics tags (`vec_exact`, `port_inorder`) follow `core.schedule`:
ring and the l <= 2 slotted construction are exact max-plus systems;
everything else keeps greedy event-loop semantics (served by the optimized
greedy loop in `core.simulator`).
"""
from __future__ import annotations

import numpy as np

from repro.core.flowvec import FlowArrays
from repro.core.model import STAGE_ID, BandwidthProfile, Schedule
from repro.core.ring import ring_allreduce_schedule, split_points
from repro.core.schedule import (optcc_multi_gpu_schedule,
                                 optcc_multi_schedule, optcc_single_schedule)


def ring_arrays(profile: BandwidthProfile, n: int) -> Schedule:
    """Columnar twin of `ring.ring_allreduce_schedule`.

    fid layout (round-major, matching the scalar generator):
      RS round t, rank r      -> fid t*p + r            (t in [0, p-1))
      self-store, rank r      -> fid (p-1)*p + r
      AG round t, rank r      -> fid p*p + t*p + r
    FIFO deps (rank's previous wire send) and chunk-delivery deps are the
    closed forms of the scalar loop's `last_recv`/`last_send` bookkeeping.
    """
    p = profile.p
    if p < 2:
        raise ValueError("need p >= 2")
    bounds = split_points(n, p)
    csz = np.diff(bounds).astype(np.float64)    # chunk sizes
    N = (2 * p - 1) * p
    src = np.empty(N, np.int64)
    dst = np.empty(N, np.int64)
    size = np.empty(N, np.float64)
    t = np.arange(p - 1)[:, None]               # rounds
    r = np.arange(p)[None, :]                   # ranks
    nxt = (r + 1) % p

    # Reduce-scatter: rank r sends chunk (r - t) mod p to r+1.
    rs = (t * p + r).ravel()
    src[rs] = np.broadcast_to(r, (p - 1, p)).ravel()
    dst[rs] = np.broadcast_to(nxt, (p - 1, p)).ravel()
    size[rs] = csz[((r - t) % p).ravel()]
    # Self-stores: chunk (r+1) mod p completed at r by RS round p-2.
    ss = (p - 1) * p + np.arange(p)
    src[ss] = dst[ss] = np.arange(p)
    size[ss] = 0.0
    # Allgather: rank r sends chunk (r + 1 - t) mod p to r+1.
    ag = (p * p + t * p + r).ravel()
    src[ag] = src[rs]
    dst[ag] = dst[rs]
    size[ag] = csz[((r + 1 - t) % p).ravel()]

    # Dependencies. RS t=0: none. RS t>0: chunk delivery (t-1, r-1) + FIFO
    # (t-1, r). Self-store: delivery (p-2, r-1). AG t=0: self-store + FIFO
    # (RS p-2, r). AG t>0: delivery (AG t-1, r-1) + FIFO (AG t-1, r).
    counts = np.empty(N, np.int64)
    counts[rs] = np.where(np.broadcast_to(t > 0, (p - 1, p)), 2, 0).ravel()
    counts[ss] = 1
    counts[ag] = 2
    indptr = np.zeros(N + 1, np.int64)
    np.cumsum(counts, out=indptr[1:])
    indices = np.empty(indptr[-1], np.int64)
    prv = (r - 1) % p
    if p > 2:
        t1 = np.arange(1, p - 1)[:, None]
        rs1 = (t1 * p + r).ravel()
        base = indptr[rs1]
        indices[base] = ((t1 - 1) * p + prv).ravel()
        indices[base + 1] = ((t1 - 1) * p + r).ravel()
    indices[indptr[ss]] = (p - 2) * p + (np.arange(p) - 1) % p
    ag0 = p * p + np.arange(p)
    indices[indptr[ag0]] = ss
    indices[indptr[ag0] + 1] = (p - 2) * p + np.arange(p)
    if p > 2:
        t1 = np.arange(1, p - 1)[:, None]
        ag1 = (p * p + t1 * p + r).ravel()
        base = indptr[ag1]
        indices[base] = (p * p + (t1 - 1) * p + prv).ravel()
        indices[base + 1] = (p * p + (t1 - 1) * p + r).ravel()

    fa = FlowArrays(src=src, dst=dst, size=size,
                    release=np.zeros(N), pri=np.full(N, np.nan),
                    nv=np.zeros(N, bool), dep_indptr=indptr,
                    dep_indices=indices)
    stage_ids = np.empty(N, np.int16)
    stage_ids[: (p - 1) * p] = STAGE_ID["RS"]
    stage_ids[(p - 1) * p: p * p] = STAGE_ID["SELF"]
    stage_ids[p * p:] = STAGE_ID["AG"]
    return Schedule(profile=profile, n=n, nic_flows=[], arrays=fa,
                    meta={"algo": "ring", "topology": "ring", "p": p,
                          "vec_exact": True, "stage_ids": stage_ids})


def optcc_single_arrays(profile: BandwidthProfile, n: int, k: int,
                        fill_bubbles: bool = True,
                        slot_release: bool = True) -> Schedule:
    """Columnar twin of `schedule._optcc_single_slotted`.

    fid layout per segment m (matching the scalar generator exactly):
      pass 1, section j:  ph-1 S1 chain hops, then the merged S2 upload
                          -> fids seg_start[m] + j*ph + [0, ph)
      star self-store     -> fid seg_start[m] + ph*ph      (fill segments)
      pass 2, section j:  S3 download, straggler self-store, ph-1 S4 hops
                          -> fids p2[m] + j*(ph+1) + [0, ph+1)
    """
    p = profile.p
    (s_rank,) = profile.stragglers
    ell = profile.slowdown[s_rank]
    ph = p - 1
    if ph < 4:
        return optcc_single_schedule(profile, n, k, fill_bubbles)
    healthy = np.array([x for x in range(p) if x != s_rank], np.int64)

    fill = fill_bubbles and ell < 2.0 and k >= 2
    if fill:
        ring_frac = ell * ph / ((p - 2) * ell + 2.0)
        ring_n = int(round(n * ring_frac))
    else:
        ring_n = n
    seg_bounds = split_points(ring_n, k)
    star_bounds = split_points(n - ring_n, max(k - 1, 1)) + ring_n
    s_i = ring_n / (k * ph) if ring_n else 1.0
    w = max(ell, 2.0)
    B = w * ph * s_i

    sec_sz = np.empty((k, ph), np.int64)
    for m in range(k):
        sec_sz[m] = np.diff(split_points(
            int(seg_bounds[m + 1] - seg_bounds[m]), ph))
    if (sec_sz <= 0).any():
        return optcc_single_schedule(profile, n, k, fill_bubbles)
    c = np.zeros(k, np.int64)                    # star block size, segment m
    if fill:
        c[:k - 1] = np.diff(star_bounds)[:k - 1]
    star = (c > 0).astype(np.int64)              # star self-store present?
    pc = np.concatenate(([0], c[:-1]))           # previous block size

    seg_len = ph * ph + star + ph * (ph + 1)
    seg_start = np.zeros(k + 1, np.int64)
    np.cumsum(seg_len, out=seg_start[1:])
    N = int(seg_start[-1])
    p2 = seg_start[:-1] + ph * ph + star         # pass-2 base per segment

    src = np.empty(N, np.int64)
    dst = np.empty(N, np.int64)
    size = np.empty(N, np.float64)
    pri = np.full(N, np.nan)
    counts = np.empty(N, np.int64)

    mm = np.arange(k)[:, None, None]             # segment      (k,1,1)
    jj = np.arange(ph)[None, :, None]            # section      (1,ph,1)
    tt = np.arange(ph - 1)[None, None, :]        # hop          (1,1,ph-1)
    nu = (jj + mm) % ph                          # owner index  (k,ph,1)
    sec3 = sec_sz[:, :, None]

    # --- pass 1: S1 chains ---------------------------------------------
    f1 = seg_start[:-1][:, None, None] + jj * ph + tt
    src[f1.ravel()] = healthy[(nu + 1 + tt) % ph].ravel()
    dst[f1.ravel()] = healthy[(nu + 2 + tt) % ph].ravel()
    size[f1.ravel()] = np.broadcast_to(sec3, f1.shape).ravel()
    pri[f1.ravel()] = (mm * B + (2 * nu + ph) * s_i + tt * s_i).ravel()
    counts[f1.ravel()] = np.broadcast_to(tt > 0, f1.shape).ravel()
    # --- pass 1: merged S2 uploads --------------------------------------
    f2 = (seg_start[:-1][:, None] + np.arange(ph)[None, :] * ph + ph - 1)
    nu2 = nu[:, :, 0]
    src[f2.ravel()] = healthy[nu2].ravel()
    dst[f2.ravel()] = s_rank
    size[f2.ravel()] = (sec_sz + c[:, None]).ravel()
    if ell <= 2.0:
        s2pri = (mm[:, :, 0] + 1) * B + (2 * nu2 + 2 * ph - 2) * s_i
    else:
        s2pri = (mm[:, :, 0] + 1) * B + ell * nu2 * s_i
    pri[f2.ravel()] = s2pri.ravel()
    counts[f2.ravel()] = 1
    # --- star self-store -------------------------------------------------
    fstar = seg_start[:-1] + ph * ph             # valid where star[m]
    sm = np.nonzero(star)[0]
    src[fstar[sm]] = dst[fstar[sm]] = s_rank
    size[fstar[sm]] = 0.0
    counts[fstar[sm]] = ph
    # --- pass 2: S3 downloads -------------------------------------------
    f3 = p2[:, None] + np.arange(ph)[None, :] * (ph + 1)
    src[f3.ravel()] = s_rank
    dst[f3.ravel()] = healthy[nu2].ravel()
    size[f3.ravel()] = (sec_sz + pc[:, None]).ravel()
    if ell <= 2.0:
        s3pri = (mm[:, :, 0] + 2) * B + (2 * nu2 + 2 * ph - 4) * s_i
    else:
        s3pri = (mm[:, :, 0] + 2) * B + ell * nu2 * s_i
    pri[f3.ravel()] = s3pri.ravel()
    counts[f3.ravel()] = np.broadcast_to(1 + ph * (pc[:, None] > 0),
                                         (k, ph)).ravel()
    # --- pass 2: straggler self-stores ----------------------------------
    fss = f3 + 1
    src[fss.ravel()] = dst[fss.ravel()] = s_rank
    size[fss.ravel()] = 0.0
    counts[fss.ravel()] = 1
    # --- pass 2: S4 allgather chains ------------------------------------
    f4 = f3[:, :, None] + 2 + tt
    src[f4.ravel()] = healthy[(nu + tt) % ph].ravel()
    dst[f4.ravel()] = healthy[(nu + 1 + tt) % ph].ravel()
    size[f4.ravel()] = np.broadcast_to(sec3, f4.shape).ravel()
    pri[f4.ravel()] = ((mm + 3) * B + (2 * nu + 2 * ph - 3) * s_i
                       + tt * s_i).ravel()
    counts[f4.ravel()] = 1

    indptr = np.zeros(N + 1, np.int64)
    np.cumsum(counts, out=indptr[1:])
    indices = np.empty(indptr[-1], np.int64)
    # chained flows (S1 t>0, S2): dep = fid - 1
    chained = f1[:, :, 1:].ravel()
    indices[indptr[chained]] = chained - 1
    indices[indptr[f2.ravel()]] = f2.ravel() - 1
    # star: all of the segment's S2 fids, section order
    if len(sm):
        indices[indptr[fstar[sm]][:, None]
                + np.arange(ph)[None, :]] = f2[sm]
    # S3: own S2 first, then the previous segment's S2 fids when a star
    # block is being returned
    indices[indptr[f3.ravel()]] = f2.ravel()
    pm = np.nonzero(pc > 0)[0]
    if len(pm):
        f3p = f3[pm].ravel()
        prev_ups = np.repeat(f2[pm - 1], ph, axis=0)
        indices[indptr[f3p][:, None] + 1 + np.arange(ph)[None, :]] = prev_ups
    # straggler self-store: own S2; S4 first hop: the S3 (fid - 2)
    indices[indptr[fss.ravel()]] = f2.ravel()
    f40 = f4[:, :, 0].ravel()
    indices[indptr[f40]] = f40 - 2
    s4rest = f4[:, :, 1:].ravel()
    indices[indptr[s4rest]] = s4rest - 1

    release = np.where(np.isnan(pri), 0.0, pri) if slot_release \
        else np.zeros(N)
    fa = FlowArrays(src=src, dst=dst, size=size, release=release, pri=pri,
                    nv=np.zeros(N, bool), dep_indptr=indptr,
                    dep_indices=indices)
    stage_ids = np.empty(N, np.int16)
    stage_ids[f1.ravel()] = STAGE_ID["S1"]
    stage_ids[f2.ravel()] = STAGE_ID["S2"]
    stage_ids[fstar[sm]] = STAGE_ID["SELF"]
    stage_ids[f3.ravel()] = STAGE_ID["S3"]
    stage_ids[fss.ravel()] = STAGE_ID["SELF"]
    stage_ids[f4.ravel()] = STAGE_ID["S4"]
    meta = {"algo": "optcc-single", "topology": "optcc", "k": k, "ell": ell,
            "fill": fill, "slotted": True, "stage_ids": stage_ids}
    if ell <= 2:          # see _optcc_single_slotted for why l > 2 is greedy
        meta["port_inorder"] = True
        meta["vec_exact"] = True
    return Schedule(profile=profile, n=n, nic_flows=[], arrays=fa, meta=meta)


def optcc_multi_arrays(profile: BandwidthProfile, n: int, k: int) -> Schedule:
    """Columnar twin of `schedule.optcc_multi_schedule`.

    Every (segment, section) block has the same internal dependency pattern
    (uploads, reduce chain, owner store, allgather chain, downloads), so the
    block is built once as a *template* of relative fids / rotation offsets
    and broadcast over all k*ph blocks; only sizes and the owner rotation
    vary per block.
    """
    p = profile.p
    stragglers = list(profile.stragglers)
    m = len(stragglers)
    healthy = np.array([x for x in range(p) if x not in set(stragglers)],
                       np.int64)
    ph = p - m
    if ph < 2:
        raise ValueError("need at least 2 healthy GPUs")

    seg_bounds = split_points(n, k)
    sec_sz = np.empty((k, ph), np.int64)
    for seg in range(k):
        sec_sz[seg] = np.diff(split_points(
            int(seg_bounds[seg + 1] - seg_bounds[seg]), ph))
    if (sec_sz <= 0).any():
        return optcc_multi_schedule(profile, n, k)

    # Block template: one entry per flow, fids relative to the block base.
    # rot: healthy-index offset from the owner rotation (nu = oidx + rot);
    # -1 means the endpoint is a fixed straggler rank (s_end).
    L = 2 * m + 2 * ph - 1
    rot_src = np.zeros(L, np.int64)
    rot_dst = np.zeros(L, np.int64)
    s_src = np.full(L, -1, np.int64)     # fixed src rank (stragglers), or -1
    s_dst = np.full(L, -1, np.int64)
    zero_sz = np.zeros(L, bool)
    rel_deps: list[list[int]] = [[] for _ in range(L)]
    for i in range(m):                   # uploads
        s_src[i] = stragglers[i]
        rot_dst[i] = 1 + (i % ph)
    for t in range(ph - 1):              # reduce chain
        e = m + t
        rot_src[e] = 1 + t
        rot_dst[e] = 2 + t
        if t > 0:
            rel_deps[e].append(e - 1)
        rel_deps[e].extend(i for i in range(m) if i % ph == t)
    e_self = m + ph - 1                  # owner self-store
    rot_src[e_self] = rot_dst[e_self] = 0
    zero_sz[e_self] = True
    ready = [m + ph - 2] + [i for i in range(m) if i % ph == ph - 1]
    rel_deps[e_self] = list(ready)
    for t in range(ph - 1):              # allgather chain
        e = m + ph + t
        rot_src[e] = t
        rot_dst[e] = t + 1
        rel_deps[e] = list(ready) if t == 0 else [e - 1]
    for i in range(m):                   # downloads
        e = m + 2 * ph - 1 + i
        rot_src[e] = 1 + (i % (ph - 1))
        s_dst[e] = stragglers[i]
        rel_deps[e] = [m + ph + (i % (ph - 1))]

    # Broadcast the template over all (seg, j) blocks.
    nblk = k * ph
    oidx = ((np.arange(ph)[None, :] + np.arange(k)[:, None]) % ph).ravel()
    bases = np.arange(nblk)[:, None] * L
    src = np.where(s_src >= 0, s_src,
                   healthy[(oidx[:, None] + rot_src) % ph]).ravel()
    dst = np.where(s_dst >= 0, s_dst,
                   healthy[(oidx[:, None] + rot_dst) % ph]).ravel()
    size = np.where(zero_sz, 0.0,
                    sec_sz.reshape(-1, 1).astype(np.float64)).ravel()
    rel_counts = np.array([len(d) for d in rel_deps], np.int64)
    rel_flat = np.array([d for ds in rel_deps for d in ds], np.int64)
    N = nblk * L
    indptr = np.zeros(N + 1, np.int64)
    np.cumsum(np.broadcast_to(rel_counts, (nblk, L)).ravel(),
              out=indptr[1:])
    indices = (rel_flat[None, :] + bases).ravel()

    fa = FlowArrays(src=src, dst=dst, size=size,
                    release=np.zeros(N), pri=np.full(N, np.nan),
                    nv=np.zeros(N, bool), dep_indptr=indptr,
                    dep_indices=indices)
    # Stage tags follow the template layout (ordering-B flavour: uploads=S3,
    # reduce chain=S1, allgather=S4, downloads=S2), tiled over all blocks.
    tmpl_stage = np.empty(L, np.int16)
    tmpl_stage[:m] = STAGE_ID["S3"]
    tmpl_stage[m:m + ph - 1] = STAGE_ID["S1"]
    tmpl_stage[m + ph - 1] = STAGE_ID["SELF"]
    tmpl_stage[m + ph:m + 2 * ph - 1] = STAGE_ID["S4"]
    tmpl_stage[m + 2 * ph - 1:] = STAGE_ID["S2"]
    return Schedule(profile=profile, n=n, nic_flows=[], arrays=fa,
                    meta={"algo": "optcc-multi", "topology": "optcc",
                          "k": k, "m": m,
                          "stage_ids": np.tile(tmpl_stage, nblk)})


def optcc_multi_gpu_arrays(profile: BandwidthProfile, n: int,
                           k: int) -> Schedule:
    """Columnar twin of `schedule.optcc_multi_gpu_schedule`.

    Like `optcc_multi_arrays`, every (cycle, segment, section) block has a
    fixed internal pattern - here one of *two* templates, since segments
    alternate ordering A (S1-S2-S3-S4) and ordering B (S3-S1-S4-S2). A
    template entry encodes each endpoint as (server-selector, local-index):
    the server is an absolute index (N1/N3 collects), the straggler server,
    or a rotation off the owner index into healthy servers; the local index
    selects from the cycle's collect order (lead last). rel deps are block-
    internal, so the CSR is a broadcast of the template over block bases.
    """
    p, g = profile.p, profile.gpus_per_server
    q = p // g
    if q < 3:
        raise ValueError("need q >= 3 servers")
    if g == 1:
        return optcc_multi_gpu_schedule(profile, n, k)
    sserver = None
    for j in range(q):
        if profile.slowdown[j * g] > 1.0:
            sserver = j
    assert sserver is not None, "no straggler server in profile"
    ell = profile.slowdown[sserver * g]
    healthy_srv = np.array([j for j in range(q) if j != sserver], np.int64)
    qh = q - 1

    part_bounds = split_points(n, g)
    sec_sz = np.empty((g, k, qh), np.int64)
    for cyc in range(g):
        c_lo = int(part_bounds[cyc])
        seg_bounds = split_points(int(part_bounds[cyc + 1]) - c_lo, k)
        for seg in range(k):
            sec_sz[cyc, seg] = np.diff(split_points(
                int(seg_bounds[seg + 1] - seg_bounds[seg]), qh))
    if (sec_sz <= 0).any():
        return optcc_multi_gpu_schedule(profile, n, k)

    # Template encoding. Endpoint = (server selector, local index):
    #   selector >= 0  absolute server (the N1/N3 collect loop),
    #   selector == -1 healthy_srv[(oidx + rot) % qh]  (owner rotation),
    #   selector == -2 the straggler server;
    # rank = server*g + lr[cyc][li], where lr = collect order (lead last).
    # Dep = (dyn, v): dyn=0 -> relative fid v; dyn=1 -> last collect hop of
    # healthy_srv[(oidx + v) % qh], i.e. that server's fold dependency -
    # the only block-varying references (collect chains sit at fixed
    # relative fids srv*(g-1).., but *which* one a rotated hop folds in
    # depends on oidx).
    LEAD = g - 1

    class _Tmpl:
        def __init__(self):
            self.rows: list[tuple] = []   # (nv, ssel, srot, sli,
            self.deps: list[list] = []    #  dsel, drot, dli, zero)
            self.stages: list[int] = []   # stage tag per row

        def add(self, nv, ssel, srot, sli, dsel, drot, dli, zero, deps,
                stage="SELF"):
            self.rows.append((nv, ssel, srot, sli, dsel, drot, dli, zero))
            self.deps.append(list(deps))
            self.stages.append(STAGE_ID[stage])
            return len(self.rows) - 1

        def nv_chain(self, sel, rot, reverse, first_deps, stage):
            """g-1 NVLink hops: collect order, or distribute (reversed)."""
            last = None
            for t in range(g - 1):
                sli, dli = (t, t + 1) if not reverse \
                    else (g - 1 - t, g - 2 - t)
                deps = list(first_deps) if last is None else [(0, last)]
                last = self.add(True, sel, rot, sli, sel, rot, dli,
                                False, deps, stage=stage)
            return last

    coll_last = lambda srv: srv * (g - 1) + g - 2   # rel fid of N1/N3 end
    s_coll = (0, coll_last(sserver))                # straggler's collect

    def build(ordering_a: bool) -> _Tmpl:
        T = _Tmpl()
        for srv in range(q):                        # N1/N3 collects
            T.nv_chain(srv, 0, False, (),
                       stage="N3" if srv == sserver else "N1")
        if ordering_a:
            last = None
            for t in range(qh - 1):                 # S1 over healthy leads
                deps = ([] if last is None else [(0, last)]) + [(1, 1 + t)]
                last = T.add(False, -1, 1 + t, LEAD, -1, 2 + t, LEAD,
                             False, deps, stage="S1")
            s2 = T.add(False, -1, qh, LEAD, -2, 0, LEAD, False,
                       [(0, last), (1, qh)], stage="S2")  # owner->straggler
            down = [(0, s2), s_coll]
            s3 = T.add(False, -2, 0, LEAD, -1, qh, LEAD, False, down,
                       stage="S3")
            T.add(False, -2, 0, LEAD, -2, 0, LEAD, True, down)
            T.nv_chain(-2, 0, True, down, stage="N2")   # on straggler srv
            ag = []
            for t in range(qh - 1):                 # S4 over healthy leads
                deps = [(0, s3)] if t == 0 else [(0, ag[-1])]
                ag.append(T.add(False, -1, t, LEAD, -1, t + 1, LEAD,
                                False, deps, stage="S4"))
            T.nv_chain(-1, 0, True, [(0, s3)], stage="N4")  # at the owner
            for t in range(1, qh):
                T.nv_chain(-1, t, True, [(0, ag[t - 1])], stage="N4")
        else:
            # Ordering B: straggler uploads raw first; chain is
            # [s_lead] + healthy leads rot 0..qh-1.
            last = T.add(False, -2, 0, LEAD, -1, 0, LEAD, False, [s_coll],
                         stage="S3")
            for t in range(1, qh):
                last = T.add(False, -1, t - 1, LEAD, -1, t, LEAD, False,
                             [(0, last), (1, t - 1)], stage="S1")
            own = [(0, last), (1, qh - 1)]
            T.add(False, -1, qh - 1, LEAD, -1, qh - 1, LEAD, True, own)
            ag = []
            for t in range(qh - 1):                 # allgather from owner
                deps = own if t == 0 else [(0, ag[-1])]
                ag.append(T.add(False, -1, qh - 1 + t, LEAD,
                                -1, qh + t, LEAD, False, deps, stage="S4"))
            s2p = T.add(False, -1, 2 * qh - 2, LEAD, -2, 0, LEAD, False,
                        [(0, ag[-1])], stage="S2")  # final return
            T.nv_chain(-1, qh - 1, True, own, stage="N4")  # at the owner
            for t in range(1, qh):
                T.nv_chain(-1, qh - 1 + t, True, [(0, ag[t - 1])],
                           stage="N4")
            T.nv_chain(-2, 0, True, [(0, s2p)], stage="N2")
        return T

    tmpl = {True: build(True), False: build(False)}
    lr_arr = np.array([[r for r in range(g) if r != cyc] + [cyc]
                       for cyc in range(g)], np.int64)

    # Block bases over the (cyc, seg, j) grid (C order, matching the scalar
    # generator's loop nest).
    LA, LB = len(tmpl[True].rows), len(tmpl[False].rows)
    seg_is_a = (np.arange(k) % 2 == 0)
    blk_len = np.where(seg_is_a, LA, LB)[None, :, None]
    blk_len = np.broadcast_to(blk_len, (g, k, qh))
    bases = np.zeros(g * k * qh + 1, np.int64)
    np.cumsum(blk_len.ravel(), out=bases[1:])
    N = int(bases[-1])
    bases3 = bases[:-1].reshape(g, k, qh)
    oidx2 = (np.arange(qh)[None, :] + np.arange(k)[:, None]) % qh  # (k, qh)

    src = np.empty(N, np.int64)
    dst = np.empty(N, np.int64)
    size = np.empty(N, np.float64)
    nv = np.empty(N, bool)
    counts = np.empty(N, np.int64)
    stage_ids = np.empty(N, np.int16)

    per_ord = {}
    for a in (True, False):
        T = tmpl[a]
        rows = np.array(T.rows, np.int64)       # (L, 8)
        dcounts = np.array([len(d) for d in T.deps], np.int64)
        dflat = np.array([dv for ds in T.deps for dv in ds],
                         np.int64).reshape(-1, 2) if any(T.deps) else \
            np.zeros((0, 2), np.int64)
        segs = np.nonzero(seg_is_a == a)[0]
        base_b = bases3[:, segs, :].ravel()     # (nb,)
        oidx_b = np.broadcast_to(oidx2[segs], (g, len(segs), qh)).ravel()
        cyc_b = np.broadcast_to(np.arange(g)[:, None, None],
                                (g, len(segs), qh)).ravel()
        sz_b = sec_sz[:, segs, :].ravel().astype(np.float64)
        L = len(rows)
        fids = base_b[:, None] + np.arange(L)[None, :]

        def endpoint(sel, rot, li):
            srv = np.where(sel >= 0, sel,
                           np.where(sel == -1,
                                    healthy_srv[(oidx_b[:, None] + rot)
                                                % qh], sserver))
            return srv * g + lr_arr[cyc_b[:, None], li[None, :]]

        src[fids] = endpoint(rows[:, 1], rows[:, 2], rows[:, 3])
        dst[fids] = endpoint(rows[:, 4], rows[:, 5], rows[:, 6])
        size[fids] = np.where(rows[:, 7] == 1, 0.0, sz_b[:, None])
        nv[fids] = (rows[:, 0] == 1)
        counts[fids] = dcounts
        stage_ids[fids] = np.array(T.stages, np.int16)[None, :]
        per_ord[a] = (base_b, oidx_b, dcounts, dflat, fids)

    indptr = np.zeros(N + 1, np.int64)
    np.cumsum(counts, out=indptr[1:])
    indices = np.empty(indptr[-1], np.int64)
    for a in (True, False):
        base_b, oidx_b, dcounts, dflat, fids = per_ord[a]
        nnz_b = int(dcounts.sum())
        if nnz_b == 0:
            continue
        dyn = dflat[:, 0] == 1
        v = dflat[:, 1]
        dyn_rel = (healthy_srv[(oidx_b[:, None] + v) % qh] * (g - 1)
                   + g - 2)
        rel = np.where(dyn, dyn_rel, v)
        pos = indptr[base_b][:, None] + np.arange(nnz_b)[None, :]
        indices[pos] = base_b[:, None] + rel

    fa = FlowArrays(src=src, dst=dst, size=size,
                    release=np.zeros(N), pri=np.full(N, np.nan),
                    nv=nv, dep_indptr=indptr, dep_indices=indices)
    return Schedule(profile=profile, n=n, nic_flows=[], arrays=fa,
                    meta={"algo": "optcc-multigpu", "topology": "optcc",
                          "k": k, "g": g, "ell": ell,
                          "stage_ids": stage_ids})


def optcc_schedule_arrays(profile: BandwidthProfile, n: int, k: int = 16,
                          fill_bubbles: bool = True) -> Schedule:
    """Arrays-first twin of `schedule.optcc_schedule` (same dispatch)."""
    stragglers = profile.stragglers
    if not stragglers:
        return ring_arrays(profile, n)
    if profile.gpus_per_server > 1:
        return optcc_multi_gpu_arrays(profile, n, k)
    if len(stragglers) == 1:
        return optcc_single_arrays(profile, n, k, fill_bubbles)
    return optcc_multi_arrays(profile, n, k)
