"""Closed-form lower bounds and achieved-time formulas from the paper.

All times are in *element-time units* of the bandwidth-bound model: a healthy
NIC moves one element per unit time. Multiply by (bytes_per_element /
nic_bytes_per_second) to get seconds.

Naming follows the paper:
  p  - total number of GPUs
  n  - vector length in elements
  l  - slowdown factor(s), l >= 1
  g  - GPUs per server (q = p/g servers)
  k  - number of pipeline segments
  m  - number of stragglers
"""
from __future__ import annotations

from typing import Sequence


# ----------------------------------------------------------------------------
# Fault-free optimum (Patarasuk & Yuan)
# ----------------------------------------------------------------------------

def t0_fault_free(p: int, n: float, g: int = 1) -> float:
    """T0 = 2(p-1) n / (g p): bandwidth-optimal homogeneous AllReduce."""
    return 2.0 * (p - 1) * n / (g * p)


# ----------------------------------------------------------------------------
# Lower bounds
# ----------------------------------------------------------------------------

def lb_single_straggler(p: int, n: float, ell: float) -> float:
    """Theorem 1: T >= max{ 2*l*(p-1) / (l*(p-1)+1), l } * n."""
    if ell < 1.0:
        raise ValueError("ell >= 1 required")
    return max(2.0 * ell * (p - 1) / (ell * (p - 1) + 1.0), ell) * n


def lb_single_straggler_tight(p: int, n: float, ell: float) -> float:
    """Theorem 6 (tight): T >= max{ 2*l*(p-1) / (l*(p-2)+2), l } * n."""
    if ell < 1.0:
        raise ValueError("ell >= 1 required")
    return max(2.0 * ell * (p - 1) / (ell * (p - 2) + 2.0), ell) * n


def lb_multi_straggler(p: int, n: float, ells: Sequence[float]) -> float:
    """Theorem 2: T >= max{ 2(p-1) / (p-m+Sum 1/l_i), l_1 } * n."""
    m = len(ells)
    if m == 0:
        return t0_fault_free(p, n)
    ell1 = max(ells)
    y0 = 2.0 * (p - 1) / (p - m + sum(1.0 / l for l in ells))
    return max(y0, ell1) * n


def lb_multi_gpu(p: int, n: float, ell: float, g: int) -> float:
    """Theorem 3: T >= (n/g) * max{ 2*l*(q-1)/(1+l*(q-1)), l }, q = p/g."""
    q = p // g
    return (n / g) * max(2.0 * ell * (q - 1) / (1.0 + ell * (q - 1)), ell)


def lb_multi_gpu_tight(p: int, n: float, ell: float, g: int) -> float:
    """Theorem 13 (tight): T >= (n/g) * max{ 2*l*(q-1)/(l*(q-2)+2), l }."""
    q = p // g
    return (n / g) * max(2.0 * ell * (q - 1) / (ell * (q - 2) + 2.0), ell)


def lower_bound(p: int, n: float, ells: Sequence[float], g: int = 1) -> float:
    """Dispatch to the tightest applicable bound for a bandwidth profile."""
    stragglers = [l for l in ells if l > 1.0]
    if not stragglers:
        return t0_fault_free(p, n, g)
    if g > 1:
        if len(stragglers) != 1:
            raise NotImplementedError("multi-straggler multi-GPU bound not in paper")
        return lb_multi_gpu_tight(p, n, stragglers[0], g)
    if len(stragglers) == 1:
        return lb_single_straggler_tight(p, n, stragglers[0])
    return lb_multi_straggler(p, n, stragglers)


# ----------------------------------------------------------------------------
# Achieved-time closed forms for OptCC (Section 4.3, Appendices C, D.3, E.4)
# ----------------------------------------------------------------------------

def optcc_time_single(p: int, n: float, ell: float, k: int) -> float:
    """Single straggler, g=1.

    l >= 2 (Eq. 1):  T = l * n * (k+1)/k
    l <  2 (Eq. 2, bubble filling):
        T = 2(p-1) l n / ((p-2) l + 2) * (k + l - 1)/k
    """
    if ell >= 2.0:
        return ell * n * (k + 1.0) / k
    return (2.0 * (p - 1) * ell * n / ((p - 2) * ell + 2.0)) * (k + ell - 1.0) / k


def optcc_time_multi(p: int, n: float, ells: Sequence[float], k: int) -> float:
    """m stragglers, g=1 (Appendix D.3).

    T_body = max{ 2(p-1) s, (l1 (p-m) + 2(m-1)) s },  s = n/(k (p-m)),
    T = (k+4) * T_body.
    """
    m = len(ells)
    ell1 = max(ells) if ells else 1.0
    s = n / (k * (p - m))
    t_body = max(2.0 * (p - 1) * s, (ell1 * (p - m) + 2.0 * (m - 1)) * s)
    return (k + 4.0) * t_body


def optcc_time_multi_gpu(p: int, n: float, ell: float, g: int, k: int) -> float:
    """Single straggler, g GPUs/server (Appendix E.4; no bubble filling).

    l >= 2: T <= l(q-1) s (k+5.5),  s = n/(g k (q-1))  ->  l n/g
    l <  2: T <= 2(q-1) s (k+5.5)                      ->  2 n/g
    """
    q = p // g
    s = n / (g * k * (q - 1))
    body = max(ell, 2.0) * (q - 1) * s
    return body * (k + 5.5)


def optcc_time(p: int, n: float, ells: Sequence[float], k: int,
               g: int = 1) -> float:
    stragglers = [l for l in ells if l > 1.0]
    if not stragglers:
        return t0_fault_free(p, n, g) * (k + 1.0) / k  # pipelined ring
    if g > 1:
        if len(stragglers) != 1:
            raise NotImplementedError
        return optcc_time_multi_gpu(p, n, stragglers[0], g, k)
    if len(stragglers) == 1:
        return optcc_time_single(p, n, stragglers[0], k)
    return optcc_time_multi(p, n, stragglers, k)


# ----------------------------------------------------------------------------
# Asymptotic (k -> inf) versions, for benchmark plots
# ----------------------------------------------------------------------------

def optcc_time_asymptotic(p: int, n: float, ells: Sequence[float],
                          g: int = 1) -> float:
    stragglers = [l for l in ells if l > 1.0]
    if not stragglers:
        return t0_fault_free(p, n, g)
    if g > 1:
        (ell,) = stragglers
        return (n / g) * max(ell, 2.0)
    if len(stragglers) == 1:
        (ell,) = stragglers
        if ell >= 2.0:
            return ell * n
        return 2.0 * (p - 1) * ell * n / ((p - 2) * ell + 2.0)
    m = len(stragglers)
    ell1 = max(stragglers)
    return max(2.0 * (p - 1) / (p - m), ell1 + 2.0 * (m - 1) / (p - m)) * n
