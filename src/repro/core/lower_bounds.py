"""Closed-form lower bounds and achieved-time formulas from the paper.

All times are in *element-time units* of the bandwidth-bound model: a healthy
NIC moves one element per unit time. Multiply by (bytes_per_element /
nic_bytes_per_second) to get seconds.

Naming follows the paper:
  p  - total number of GPUs
  n  - vector length in elements
  l  - slowdown factor(s), l >= 1
  g  - GPUs per server (q = p/g servers)
  k  - number of pipeline segments
  m  - number of stragglers
"""
from __future__ import annotations

from typing import Sequence


# ----------------------------------------------------------------------------
# Fault-free optimum (Patarasuk & Yuan)
# ----------------------------------------------------------------------------

def t0_fault_free(p: int, n: float, g: int = 1) -> float:
    """T0 = 2(p-1) n / (g p): bandwidth-optimal homogeneous AllReduce."""
    return 2.0 * (p - 1) * n / (g * p)


# ----------------------------------------------------------------------------
# Lower bounds
# ----------------------------------------------------------------------------

def lb_single_straggler(p: int, n: float, ell: float) -> float:
    """Theorem 1: T >= max{ 2*l*(p-1) / (l*(p-1)+1), l } * n."""
    if ell < 1.0:
        raise ValueError("ell >= 1 required")
    return max(2.0 * ell * (p - 1) / (ell * (p - 1) + 1.0), ell) * n


def lb_single_straggler_tight(p: int, n: float, ell: float) -> float:
    """Theorem 6 (tight): T >= max{ 2*l*(p-1) / (l*(p-2)+2), l } * n."""
    if ell < 1.0:
        raise ValueError("ell >= 1 required")
    return max(2.0 * ell * (p - 1) / (ell * (p - 2) + 2.0), ell) * n


def lb_multi_straggler(p: int, n: float, ells: Sequence[float]) -> float:
    """Theorem 2: T >= max{ 2(p-1) / (p-m+Sum 1/l_i), l_1 } * n."""
    m = len(ells)
    if m == 0:
        return t0_fault_free(p, n)
    ell1 = max(ells)
    y0 = 2.0 * (p - 1) / (p - m + sum(1.0 / l for l in ells))
    return max(y0, ell1) * n


def lb_multi_gpu(p: int, n: float, ell: float, g: int) -> float:
    """Theorem 3: T >= (n/g) * max{ 2*l*(q-1)/(1+l*(q-1)), l }, q = p/g."""
    q = p // g
    return (n / g) * max(2.0 * ell * (q - 1) / (1.0 + ell * (q - 1)), ell)


def lb_multi_gpu_tight(p: int, n: float, ell: float, g: int) -> float:
    """Theorem 13 (tight): T >= (n/g) * max{ 2*l*(q-1)/(l*(q-2)+2), l }."""
    q = p // g
    return (n / g) * max(2.0 * ell * (q - 1) / (ell * (q - 2) + 2.0), ell)


def lower_bound(p: int, n: float, ells: Sequence[float], g: int = 1) -> float:
    """Dispatch to the tightest applicable bound for a bandwidth profile."""
    stragglers = [l for l in ells if l > 1.0]
    if not stragglers:
        return t0_fault_free(p, n, g)
    if g > 1:
        if len(stragglers) != 1:
            raise NotImplementedError("multi-straggler multi-GPU bound not in paper")
        return lb_multi_gpu_tight(p, n, stragglers[0], g)
    if len(stragglers) == 1:
        return lb_single_straggler_tight(p, n, stragglers[0])
    return lb_multi_straggler(p, n, stragglers)


def timeline_lower_bound(profile, timeline, n: float) -> float:
    """Lower bound for a run under a `FaultTimeline` (core.model).

    Uses the static bound of the per-rank *best-ever* profile
    (`timeline.min_profile`): the flow model is monotone in the slowdown
    vector (every flow is pointwise no slower when every rank is at its
    fastest-ever rate), so the static bound of that profile bounds any
    schedule under the timeline. Deliberately not an integral/averaged
    bound - those are not sound when the adversary controls *when* work is
    scheduled relative to the fault windows.
    """
    best = timeline.min_profile(profile)
    ells = [l for l in best.slowdown if l > 1.0]
    return lower_bound(best.p, n, ells, best.gpus_per_server)


# ----------------------------------------------------------------------------
# Achieved-time closed forms for OptCC (Section 4.3, Appendices C, D.3, E.4)
#
# These are *calibrated* against the repo's flow-model simulator so that
# Plan.predicted_time is an operator-grade estimate, not just an upper-bound
# sketch: tests/test_schedule_time.py gates simulated/predicted within 10%
# at k=4 across every regime. The leading terms are the paper's; the
# pipeline-head/drain constants are fits to the simulator (the paper's
# (k+1)/k-style forms count one body of fill where the constructions here
# pay a small constant number of bodies). Constants assume the paper's
# minimum (g-1)x NVLink provisioning; with faster NVLink (e.g. DGX 12x)
# the multi-GPU form slightly over-predicts, conservatively.
# ----------------------------------------------------------------------------

def optcc_time_single(p: int, n: float, ell: float, k: int) -> float:
    """Single straggler, g=1 (Section 4.3 / Appendix C with bubble filling).

    Slotted construction (p - 1 >= 4), measured exactly:
      l >= 2:  T = (n/k) (l (k+2) + 5 - 6/(p-1))
      l <  2:  T = s_hat (2 (p-1)(k+2) + 5 (p-1) - 6),
               s_hat = l n / (((p-2) l + 2) k)   [straggler slot width]
    The l >= 2 form is bit-exact vs the simulator; the l < 2 form is within
    ~3.5% (greedy bubble filling shifts a few slots by (2 - l) s each).
    For p - 1 < 4 the generator uses the legacy alternate-orderings
    construction; those constants are separate fits.
    """
    ph = p - 1
    if ph < 4:
        if ell >= 2.0:
            return (n / k) * (ell * k + 2.5 + 0.2 * ell)
        s_hat = ell * n / (((p - 2) * ell + 2.0) * k)
        return s_hat * (7.3 * k + 4.0)
    if ell >= 2.0:
        return (n / k) * (ell * (k + 2.0) + 5.0 - 6.0 / ph)
    s_hat = ell * n / (((p - 2) * ell + 2.0) * k)
    return s_hat * (2.0 * ph * (k + 2.0) + 5.0 * ph - 6.0)


def optcc_time_multi(p: int, n: float, ells: Sequence[float], k: int) -> float:
    """m stragglers, g=1 (Appendix D.3).

    Per-segment body (s = n/(k (p-m)) is the healthy chunk width):

      T_body = max{ l1 (p-m) + 2(m-1),            # straggler upload-bound
                    2(p-1) + Sum_i (l_i - 1) } s  # healthy recv-port bound:
                                                  # every straggler's chunk
                                                  # arrives l_i-times dilated
                                                  # at some healthy recv port

    T = T_body k + T_fill s, with the pipeline head/drain fill fitted per
    regime against the simulator at k=4 (l2 = second-largest slowdown):

      straggler-bound: T_fill = 0.66(p-1) + 4.14 (m-1) l2 + 0.89 l2 (p-m)
      healthy-bound:   T_fill = 1.82 l2 (p-m) - 0.16 (m-1) l1

    Max |sim/pred - 1| over p in {8..64}, m <= 4, l in [8/7, 8]: 6.9% / 5.7%.
    """
    m = len(ells)
    ell1 = max(ells) if ells else 1.0
    srt = sorted(ells, reverse=True)
    ell2 = srt[1] if m > 1 else 1.0
    s = n / (k * (p - m))
    body_straggler = ell1 * (p - m) + 2.0 * (m - 1)
    body_healthy = 2.0 * (p - 1) + sum(l - 1.0 for l in ells)
    if body_straggler >= body_healthy:
        body = body_straggler
        fill = (0.66 * (p - 1) + 4.14 * (m - 1) * ell2
                + 0.89 * ell2 * (p - m))
    else:
        body = body_healthy
        fill = 1.82 * ell2 * (p - m) - 0.16 * (m - 1) * ell1
    return s * (body * k + fill)


def optcc_time_multi_gpu(p: int, n: float, ell: float, g: int, k: int) -> float:
    """Single straggler server, g GPUs/server (Appendix E.4 leading term).

    T = s ((q-1)(w k + fill) + tail),  s = n/(g k (q-1)),  w = max(l, 2).
    Under the paper's minimal (g-1)x NVLink provisioning and g > 2 the
    zero-slack NVLink chains congest the greedy dispatcher, costing an extra
    ~1.2 s (q-1) per segment (w += 1.2); the fills are simulator fits at k=4:

      g == 2: fill = 2.17 min(l, 2),                    tail = 1.61 l - 2.63
      g >= 4: fill = 2.252 min(l, 2) + 0.388 max(l - 2, 0) - 1.073,
              tail = 0.763 min(l, 2)

    Max |sim/pred - 1| over q in {3..32}, l in [8/7, 8]: 8.5% (g=2), 9.4%
    (g in {4, 8}) - the greedy NVLink congestion is not a smooth function
    of l, so the residual is scatter, not a missing term.
    """
    q = p // g
    s = n / (g * k * (q - 1))
    if g == 2:
        w = max(ell, 2.0)
        return s * ((q - 1) * (w * k + 2.17 * min(ell, 2.0))
                    + 1.61 * ell - 2.63)
    w = max(ell, 2.0) + 1.2
    fill = 2.252 * min(ell, 2.0) + 0.388 * max(ell - 2.0, 0.0) - 1.073
    return s * ((q - 1) * (w * k + fill) + 0.763 * min(ell, 2.0))


def optcc_time(p: int, n: float, ells: Sequence[float], k: int,
               g: int = 1) -> float:
    stragglers = [l for l in ells if l > 1.0]
    if not stragglers:
        # The FIFO ring generator builds a *flat* p-GPU ring over NICs and
        # achieves 2(p-1)n/p exactly in the flow model (tests/
        # test_schedule_time.py pins this). With g > 1 that is a factor g
        # above the hierarchical optimum t0_fault_free(p, n, g); predict
        # what the schedule does, not the unimplemented hierarchical ring.
        return t0_fault_free(p, n, 1)
    if g > 1:
        if len(stragglers) != 1:
            raise NotImplementedError
        return optcc_time_multi_gpu(p, n, stragglers[0], g, k)
    if len(stragglers) == 1:
        return optcc_time_single(p, n, stragglers[0], k)
    return optcc_time_multi(p, n, stragglers, k)


# ----------------------------------------------------------------------------
# Per-topology bounds and time models (schedule registry, PR 10)
#
# Unlike the ell-parameterized paper bounds above, these take the full
# BandwidthProfile: the tree/torus bounds depend on *which* rank is slow
# (an interior tree rank hurts; a leaf barely does), not just the multiset
# of slowdowns. Each bound is the port-occupancy argument - a rank's NIC
# send (resp. recv) port must serialize all bytes it sends (receives),
# each at >= size * slowdown - evaluated on the exact integer splits the
# matching generator in `core.topologies` emits, so rounding can never
# push the bound above the simulated time. The time models are reporting
# estimates (registry `auto=False` entries never steer `make_plan`).
# ----------------------------------------------------------------------------

def lb_dbtree(profile, n: float) -> float:
    """Double binary tree: rank r's NIC moves `dbtree_traffic[r]` in each
    direction (send == recv), so T >= max_r traffic[r] * l_r."""
    import numpy as np

    from repro.core.topologies import dbtree_traffic
    traffic = dbtree_traffic(profile.p, n)
    return float(np.max(traffic * np.asarray(profile.slowdown)))


def dbtree_time(profile, n: float, k: int) -> float:
    """Traffic bound plus the up-and-down pipeline ramp: ~2 * depth hops of
    one n/(2k) segment each at the slowest rate."""
    import math
    depth = math.ceil(math.log2(profile.p + 1))
    return (lb_dbtree(profile, n)
            + 2.0 * depth * (n / (2.0 * max(k, 1))) * max(profile.slowdown))


def lb_torus2d(profile, n: float) -> float:
    """2-D torus: T >= max_r max(send_r, recv_r) * l_r over the exact
    4-phase traffic of the generator's splits."""
    import numpy as np

    from repro.core.topologies import torus2d_traffic
    send, recv = torus2d_traffic(profile.p, n)
    sl = np.asarray(profile.slowdown)
    return float(np.max(np.maximum(send, recv) * sl))


def torus2d_time(profile, n: float, k: int = 0) -> float:
    """Sum over the 4 phases of that phase's slowest port (the phases are
    barrier-separated per chunk, so this always dominates `lb_torus2d`)."""
    import numpy as np

    from repro.core.topologies import torus2d_traffic
    sl = np.asarray(profile.slowdown)
    total = 0.0
    for send, recv in torus2d_traffic(profile.p, n, per_phase=True):
        total += float(np.max(np.maximum(send, recv) * sl))
    return total


def _hier_lead_ells(profile) -> list:
    g = profile.gpus_per_server
    leads = [profile.slowdown[s * g] for s in range(profile.num_servers)]
    return [l for l in leads if l > 1.0]


def lb_hierarchical(profile, n: float) -> float:
    """Hierarchical (NVLink reduce per server + inter-server collective over
    one lead per server): the leads' NICs execute a q-rank AllReduce of the
    server sums (universal q-rank bound at the leads' *actual* NIC rates),
    and every non-lead GPU must push its full vector out - and pull the
    full result back in - over NVLink."""
    q = profile.num_servers
    return max(lower_bound(q, n, _hier_lead_ells(profile), 1),
               n / profile.nvlink_rate)


def hierarchical_time(profile, n: float, k: int) -> float:
    """Inner OptCC/ring prediction on the server-level profile plus the
    NVLink collect + distribute chains (imperfectly overlapped)."""
    from repro.core.topologies import server_slowdowns
    q = profile.num_servers
    inner_ells = [l for l in server_slowdowns(profile) if l > 1.0]
    if inner_ells:
        inner = optcc_time(q, n, inner_ells, k, 1)
    else:
        inner = t0_fault_free(q, n, 1)
    return inner + 2.0 * n / profile.nvlink_rate


# ----------------------------------------------------------------------------
# Asymptotic (k -> inf) versions, for benchmark plots
# ----------------------------------------------------------------------------

def optcc_time_asymptotic(p: int, n: float, ells: Sequence[float],
                          g: int = 1) -> float:
    stragglers = [l for l in ells if l > 1.0]
    if not stragglers:
        return t0_fault_free(p, n, g)
    if g > 1:
        (ell,) = stragglers
        return (n / g) * max(ell, 2.0)
    if len(stragglers) == 1:
        (ell,) = stragglers
        if ell >= 2.0:
            return ell * n
        return 2.0 * (p - 1) * ell * n / ((p - 2) * ell + 2.0)
    m = len(stragglers)
    ell1 = max(stragglers)
    return max(2.0 * (p - 1) / (p - m), ell1 + 2.0 * (m - 1) / (p - m)) * n
