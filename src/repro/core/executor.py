"""Data-level execution of a flow schedule: verifies AllReduce correctness.

The executor runs the *same* Schedule object the simulator times, but instead
of tracking time it moves real numpy payloads. A schedule is correct iff
after executing all flows in any dependency-respecting order, every rank's
output vector equals sum_i x_i (Section 3's correctness definition).

Flow semantics (see core.model.Op):
  sender payload = bufs[src][key] if present else x[src][lo:hi]
  ACCUM at dst:   bufs[dst][key] = (bufs[dst][key] or x[dst][lo:hi]) + payload
  STORE at dst:   out[dst][lo:hi] = payload; bufs[dst][key] = payload

Because ACCUM initializes once with the receiver's own contribution and then
order-independently accumulates, the executor result is invariant to the
interleaving the simulator happens to choose - we execute in topological
(fid) order for determinism.
"""
from __future__ import annotations

import numpy as np

from repro.core.model import Op, Schedule


def execute(schedule: Schedule, x: np.ndarray) -> np.ndarray:
    """Execute `schedule` on inputs x of shape (p, n); returns out (p, n).

    Raises if a flow references an uninitialized range inconsistently; the
    caller asserts out == x.sum(0) per rank.
    """
    p, n = x.shape
    if p != schedule.profile.p:
        raise ValueError(f"x has {p} ranks, profile has {schedule.profile.p}")
    out = np.full((p, n), np.nan, dtype=x.dtype)
    bufs: list[dict] = [dict() for _ in range(p)]

    flows = sorted(schedule.nic_flows + schedule.nvlink_flows,
                   key=lambda f: f.fid)
    done: set[int] = set()

    def apply_part(src: int, dst: int, lo: int, hi: int, op: Op, key: tuple):
        if hi <= lo:
            return
        payload = bufs[src].get(key)
        if payload is None:
            payload = x[src, lo:hi].copy()
        if op is Op.ACCUM:
            base = bufs[dst].get(key)
            if base is None:
                base = x[dst, lo:hi].copy()
            bufs[dst][key] = base + payload
        elif op is Op.STORE:
            out[dst, lo:hi] = payload
            bufs[dst][key] = payload
        else:
            raise ValueError(f"unknown op {op}")

    for f in flows:
        for d in f.deps:
            if d not in done:
                raise ValueError(
                    f"flow {f.fid} executed before dependency {d}; "
                    "generator must emit flows in topological fid order")
        apply_part(f.src, f.dst, int(f.lo), int(f.hi), f.op, f.key)
        for (lo, hi, op, key) in f.extra:
            apply_part(f.src, f.dst, int(lo), int(hi), op, key)
        done.add(f.fid)
    return out


def verify_allreduce(schedule: Schedule, x: np.ndarray,
                     rtol: float = 1e-6, atol: float = 1e-6) -> None:
    """Assert every rank ends with the element-wise sum of all inputs."""
    out = execute(schedule, x)
    expected = x.sum(axis=0)
    for r in range(x.shape[0]):
        np.testing.assert_allclose(
            out[r], expected, rtol=rtol, atol=atol,
            err_msg=f"rank {r} does not hold the global sum")
