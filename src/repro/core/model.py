"""Data model for the bandwidth-bound flow abstraction of the paper.

A *flow* is a point-to-point transfer of `size` elements from `src` to `dst`
(Section 4.1). The bandwidth-bound model (Section 3, "Problem setting"):

  - a healthy NIC transmits one element per time unit;
  - a NIC with slowdown factor l > 1 takes l time units per element;
  - each NIC port (send side / recv side) carries at most one flow at a time;
  - per-message latency and cold-start terms are excluded.

A flow's duration is `size * max(l_src, l_dst)`: the slower endpoint throttles
the transfer (the paper's Stage-2/3 flows take l*s even though one endpoint is
healthy).

Flows carry semantic *tags* so that a single schedule object can be both
timed (core.simulator) and executed on real data (core.executor).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional, Sequence


# Canonical pipeline-stage vocabulary for flow tagging (observability layer).
# Generators stamp every flow with one of these so telemetry / critical-path
# attribution can localize a regression to a stage instead of a scalar:
#   S1-S4  the paper's OptCC stages (reduce-scatter chain, upload to the
#          straggler, download from the straggler, allgather); the multi-
#          straggler schedule reuses them per its ordering-B flavour
#          (uploads = S3, ring = S1, allgather = S4, downloads = S2);
#   RS/AG  plain ring reduce-scatter / allgather rounds;
#   SELF   zero-size local bookkeeping flows (never wire traffic);
#   STAR   Appendix-C star flows where they are separate wire transfers
#          (legacy generator; the slotted construction merges them into
#          S2/S3);
#   N1-N4  the multi-GPU NVLink phases (collect healthy / distribute
#          straggler / collect straggler / distribute healthy).
# Stage ids live in ``Schedule.meta["stage_ids"]`` (int16 array indexed by
# fid) - metadata only, never consulted by the simulator's timing paths.
STAGE_NAMES = ("S1", "S2", "S3", "S4", "RS", "AG", "SELF", "STAR",
               "N1", "N2", "N3", "N4")
STAGE_ID = {name: i for i, name in enumerate(STAGE_NAMES)}


class Op(enum.Enum):
    """What the receiver does with an incoming flow's payload.

    Sender semantics are uniform: a flow sends ``bufs[src][key]`` if that
    buffer exists, else the sender's raw input slice ``x[src][lo:hi]``
    (chain starts / ordering-B straggler uploads).
    """

    # bufs[dst][key] = (bufs[dst][key] if present else x[dst][lo:hi]) + payload
    # Init-once-with-own-contribution + order-independent accumulation: this
    # single primitive expresses ring reduce-scatter hops, straggler uploads,
    # multi-straggler owner combines, NVLink collects and star reduces.
    ACCUM = "accum"
    # out[dst][lo:hi] = payload; bufs[dst][key] = payload (store & forward:
    # allgather hops, straggler downloads, NVLink distributes).
    STORE = "store"


@dataclasses.dataclass(frozen=True)
class Flow:
    """One point-to-point transfer.

    Attributes:
      fid: unique id (also the priority: lower fid = earlier in schedule order).
      src/dst: GPU ranks.
      size: number of elements (float allowed; fractional sections appear in
        bubble filling where s' is generally non-integral in element-time units).
      deps: fids that must complete before this flow may start.
      lo/hi: element range [lo, hi) of the vector this flow carries.
      op: receiver semantics (see Op).
      key: opaque tuple used by the executor to name partial-sum buffers.
      pri: planned start time in the paper's slotted timeline (Figures 5-6).
        The simulator uses it as the dispatch priority (work-conserving: a
        flow may still start early if ports are free). None -> fid order.
    """

    fid: int
    src: int
    dst: int
    size: float
    deps: tuple[int, ...]
    lo: float = 0.0
    hi: float = 0.0
    op: Op = Op.STORE
    key: tuple = ()
    pri: Optional[float] = None
    release: float = 0.0   # hard earliest-start time (slotted schedules)
    # Extra payload parts packed into the same wire transfer (Appendix C:
    # bubble filling *enlarges* Stage-2/3 flows to carry the P2P star chunk).
    # Each entry is (lo, hi, op, key); `size` covers main + extras.
    extra: tuple = ()

    @property
    def priority(self) -> tuple[float, int]:
        return (self.pri if self.pri is not None else float(self.fid),
                self.fid)


@dataclasses.dataclass(frozen=True)
class BandwidthProfile:
    """Per-rank NIC slowdown factors. slowdown[i] == 1.0 means healthy.

    For the multi-GPU/server setting, `gpus_per_server` > 1 and ranks are
    grouped server-major: server j owns ranks [j*g, (j+1)*g). NVLink rate is
    (g-1)x the NIC rate per the paper's provisioning assumption.
    """

    p: int
    slowdown: tuple[float, ...]
    gpus_per_server: int = 1
    # NVLink per-direction bandwidth as a multiple of one NIC. None ->
    # the paper's provisioning assumption (g-1)x, the *minimum* that hides
    # intra-server traffic. Real hardware has more headroom (DGX A100:
    # 2400 Gbps NVLink vs 200 Gbps NIC = 12x; paper footnote 4).
    nvlink_mult: float | None = None

    @property
    def nvlink_rate(self) -> float:
        if self.nvlink_mult is not None:
            return self.nvlink_mult
        return max(self.gpus_per_server - 1, 1)

    def __post_init__(self):
        if len(self.slowdown) != self.p:
            raise ValueError(f"slowdown must have length p={self.p}")
        if any(l < 1.0 for l in self.slowdown):
            raise ValueError("slowdown factors must be >= 1")
        if self.p % self.gpus_per_server:
            raise ValueError("p must be divisible by gpus_per_server")

    @classmethod
    def healthy(cls, p: int, g: int = 1) -> "BandwidthProfile":
        return cls(p=p, slowdown=(1.0,) * p, gpus_per_server=g)

    @classmethod
    def single_straggler(cls, p: int, ell: float, straggler: int = 0,
                         g: int = 1) -> "BandwidthProfile":
        sl = [1.0] * p
        if g == 1:
            sl[straggler] = ell
        else:
            # straggler is a *server* index; all its GPUs' NICs degrade (PXN).
            for r in range(straggler * g, (straggler + 1) * g):
                sl[r] = ell
        return cls(p=p, slowdown=tuple(sl), gpus_per_server=g)

    @classmethod
    def multi_straggler(cls, p: int, ells: Sequence[float],
                        stragglers: Optional[Sequence[int]] = None
                        ) -> "BandwidthProfile":
        if stragglers is None:
            stragglers = list(range(len(ells)))
        sl = [1.0] * p
        for r, l in zip(stragglers, ells):
            sl[r] = l
        return cls(p=p, slowdown=tuple(sl))

    @property
    def stragglers(self) -> tuple[int, ...]:
        return tuple(i for i, l in enumerate(self.slowdown) if l > 1.0)

    @property
    def max_ell(self) -> float:
        return max(self.slowdown)

    @property
    def num_servers(self) -> int:
        return self.p // self.gpus_per_server


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One rate change: from time ``t`` on, ``rank``'s NIC slowdown is
    ``ell`` (absolute, not a delta; ``ell == 1.0`` means fully recovered).

    Times are in element-time units of the flow model, the same clock the
    simulator runs on. Events at ``t == 0`` rewrite the initial profile
    (a recovery at t=0 on a degraded base is exactly the healthy cluster).
    """

    t: float
    rank: int
    ell: float

    def __post_init__(self):
        if not (self.t >= 0.0 and self.t == self.t and self.t != float("inf")):
            raise ValueError(f"event time must be finite and >= 0, got {self.t}")
        if self.ell < 1.0:
            raise ValueError(f"slowdown must be >= 1, got {self.ell}")
        if self.rank < 0:
            raise ValueError(f"rank must be >= 0, got {self.rank}")


@dataclasses.dataclass(frozen=True)
class FaultTimeline:
    """A failure timeline: piecewise-constant per-rank slowdowns layered on a
    BandwidthProfile. Real clusters degrade *over time* - NICs flap, traffic
    reroutes, links recover mid-collective (the R2CCL failure catalogs, the
    Alibaba-GPU-2020 / AcmeTrace fault traces) - and a static profile cannot
    express that. The timeline is the additive piece: the base profile gives
    the slowdown vector at t=0 and each event rewrites one rank's rate from
    its time on. Only NIC rates vary; NVLink is never degraded (same
    assumption as the static model).

    Events are kept sorted by (t, rank, insertion order); later events on the
    same rank win. The timeline itself is profile-agnostic - `segments`
    resolves it against a concrete base profile into breakpoints + per-segment
    slowdown vectors, skipping no-op changes so a timeline that never alters
    the effective vector has no breakpoints at all (and the simulator then
    takes the static path, bit-for-bit).
    """

    events: tuple[FaultEvent, ...] = ()

    def __post_init__(self):
        evs = tuple(self.events)
        if any(not isinstance(e, FaultEvent) for e in evs):
            raise TypeError("events must be FaultEvent instances; "
                            "use FaultTimeline.make for (t, rank, ell) tuples")
        order = sorted(range(len(evs)), key=lambda i: (evs[i].t, evs[i].rank, i))
        object.__setattr__(self, "events", tuple(evs[i] for i in order))

    @classmethod
    def make(cls, events: Sequence) -> "FaultTimeline":
        """Build from an iterable of FaultEvent or (t, rank, ell) tuples."""
        return cls(tuple(e if isinstance(e, FaultEvent) else FaultEvent(*e)
                         for e in events))

    def slowdown_at(self, profile: "BandwidthProfile",
                    t: float) -> tuple[float, ...]:
        """Effective slowdown vector at time t (events with ``e.t <= t``
        applied to the base profile, in order)."""
        sl = list(profile.slowdown)
        for e in self.events:
            if e.t > t:
                break
            if e.rank >= profile.p:
                raise ValueError(f"event rank {e.rank} >= p={profile.p}")
            sl[e.rank] = e.ell
        return tuple(sl)

    def profile_at(self, profile: "BandwidthProfile",
                   t: float) -> "BandwidthProfile":
        """The static BandwidthProfile in effect at time t."""
        return dataclasses.replace(profile, slowdown=self.slowdown_at(profile, t))

    def segments(self, profile: "BandwidthProfile"
                 ) -> tuple[tuple[float, ...], tuple[tuple[float, ...], ...]]:
        """Resolve against a base profile: (breakpoints, vectors).

        breakpoints are strictly increasing times > 0 at which the effective
        slowdown vector *changes value*; vectors[j] is the slowdown tuple in
        force on [breakpoints[j-1], breakpoints[j]) (vectors[0] from t=0,
        already including any t=0 events). len(vectors) == len(breaks) + 1.
        No-op events (rewriting a rank to its current value) produce no
        breakpoint, so a constant timeline resolves to ([], [initial]).
        """
        sl = list(profile.slowdown)
        for e in self.events:
            if e.rank >= profile.p:
                raise ValueError(f"event rank {e.rank} >= p={profile.p}")
        i = 0
        evs = self.events
        while i < len(evs) and evs[i].t <= 0.0:
            sl[evs[i].rank] = evs[i].ell
            i += 1
        breaks: list[float] = []
        vectors: list[tuple[float, ...]] = [tuple(sl)]
        while i < len(evs):
            t = evs[i].t
            while i < len(evs) and evs[i].t == t:
                sl[evs[i].rank] = evs[i].ell
                i += 1
            vec = tuple(sl)
            if vec != vectors[-1]:
                breaks.append(t)
                vectors.append(vec)
        return tuple(breaks), tuple(vectors)

    def is_constant(self, profile: "BandwidthProfile") -> bool:
        """True when the effective slowdown vector never changes after t=0
        (the simulator then reduces to the static profile_at(0) run)."""
        return not self.segments(profile)[0]

    def after(self, t0: float) -> "FaultTimeline":
        """The residual timeline seen by a plan launched at absolute time t0:
        events at or before t0 are dropped (fold them into the launch profile
        via `profile_at`), later ones shift to the plan's local clock."""
        return FaultTimeline(tuple(
            FaultEvent(e.t - t0, e.rank, e.ell)
            for e in self.events if e.t > t0))

    def changes(self, profile: "BandwidthProfile"
                ) -> dict[int, list[tuple[float, float]]]:
        """Per-rank effective value changes after t=0, resolved against the
        base profile: {rank: [(t, new_ell), ...]} with strictly increasing
        t per rank. No-op events are dropped (same semantics as `segments`);
        ranks that never change are absent. This is the per-rank view the
        fault-detection layer (`repro.detect`) samples through its probe
        lens, and what `comms.fault.FailureInjector.to_timeline` round-trips
        through in tests."""
        breaks, vectors = self.segments(profile)
        out: dict[int, list[tuple[float, float]]] = {}
        for j, t in enumerate(breaks):
            prev, cur = vectors[j], vectors[j + 1]
            for r in range(profile.p):
                if cur[r] != prev[r]:
                    out.setdefault(r, []).append((t, cur[r]))
        return out

    def min_profile(self, profile: "BandwidthProfile") -> "BandwidthProfile":
        """Per-rank best-ever rates over the whole timeline: the static
        profile in which every NIC always runs at the fastest rate it ever
        reaches. Any run under the timeline is pointwise no faster than the
        same run under this profile (rates only get better), so its static
        lower bound is a valid bound for the time-varying run."""
        _, vectors = self.segments(profile)
        best = [min(vec[r] for vec in vectors) for r in range(profile.p)]
        return dataclasses.replace(profile, slowdown=tuple(best))


@dataclasses.dataclass
class Schedule:
    """A complete flow schedule plus NVLink flows (multi-GPU setting).

    nic_flows are timed against NIC ports; nvlink_flows against per-GPU
    NVLink ports at rate (g-1)x NIC speed. For g == 1, nvlink_flows is empty.

    Every generator stamps a `meta` dict honoring the key contract below
    (`validate_schedule_meta`); extra generator-specific keys are fine.

      algo       str, the concrete construction ("ring", "optcc-single",
                 "optcc-multi", "optcc-multigpu", "hierarchical", "dbtree",
                 "torus2d"). What `Plan.algo` and sweep artifacts report.
      topology   str, the schedule-registry name the construction belongs
                 to (`planner.topology_of(algo)`): the optcc-* variants all
                 map to "optcc". What `make_plan(algo=...)` accepts.
      stage_ids  int array of len == num_flows mapping each flow (by fid)
                 to its pipeline stage in STAGE_NAMES, for telemetry
                 attribution (repro.obs).
    """

    profile: BandwidthProfile
    n: float                      # total vector length (elements)
    nic_flows: list[Flow]
    nvlink_flows: list[Flow] = dataclasses.field(default_factory=list)
    meta: dict = dataclasses.field(default_factory=dict)
    # Columnar flow graph (core.flowvec.FlowArrays) built by the vectorized
    # generators. When set, nic_flows/nvlink_flows may be empty: the sweep
    # hot path simulates straight from the arrays and never pays for Flow
    # object construction. Schedules that need per-flow semantics (executor,
    # correctness tests) are generated with materialize=True instead.
    arrays: object = None

    @property
    def num_flows(self) -> int:
        if self.arrays is not None:
            return self.arrays.nflows
        return len(self.nic_flows) + len(self.nvlink_flows)


def validate_schedule_meta(schedule: Schedule) -> None:
    """Assert `schedule.meta` honors the documented key contract (Schedule
    docstring): non-empty `algo`/`topology` strings and a full-length
    `stage_ids` vector with in-range stage indices. `simulate` runs this in
    debug mode (REPRO_DEBUG=1) so a generator that forgets a key fails the
    first simulation, not a sweep artifact check three layers up."""
    meta = schedule.meta
    for key in ("algo", "topology"):
        val = meta.get(key)
        if not (isinstance(val, str) and val):
            raise ValueError(
                f"schedule.meta[{key!r}] must be a non-empty str, got "
                f"{val!r} (algo={meta.get('algo')!r})")
    stage_ids = meta.get("stage_ids")
    if stage_ids is None:
        raise ValueError(
            f"schedule.meta['stage_ids'] missing (algo={meta['algo']!r})")
    import numpy as np
    sids = np.asarray(stage_ids)
    if sids.shape != (schedule.num_flows,):
        raise ValueError(
            f"stage_ids has shape {sids.shape}, expected "
            f"({schedule.num_flows},) (algo={meta['algo']!r})")
    if sids.size and (sids.min() < 0 or sids.max() >= len(STAGE_NAMES)):
        raise ValueError(
            f"stage_ids values outside [0, {len(STAGE_NAMES)}) "
            f"(algo={meta['algo']!r})")
