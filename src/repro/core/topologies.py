"""Topology-aware AllReduce schedules beyond the single OptCC ring.

Three additional generators for the schedule registry (`core.registry`):

  * hierarchical_schedule - intra-server NVLink reduce + inter-server OptCC
    over one lead rank per server. The inner collective is whatever
    `optcc_schedule` dispatches for the *server-level* profile (each
    server's slowdown is the max over its ranks), so a single slow server
    gets the paper's straggler treatment while the NVLink fan-in/fan-out
    keeps the other g-1 GPUs per box off the NICs entirely.
  * dbtree_schedule - double-binary-tree baseline (NCCL's tree algorithm):
    two balanced trees with disjoint interior roles, each reducing and
    broadcasting one half of the vector. Latency-optimal in depth but moves
    ~2n per interior rank, so it loses to ring/OptCC on bandwidth - it is
    here as the baseline the mesh/tree literature compares against.
  * torus2d_schedule - 2-D torus reduce per *Highly Available Data Parallel
    ML Training on Mesh Networks* (PAPERS.md): row reduce-scatter, column
    reduce-scatter, column allgather, row allgather. Per-rank traffic is
    exactly 2n(p-1)/p (bandwidth-optimal) while every ring is only r or c
    long, which shortens the dependency chains a slow rank sits on.

All three emit flows in topological fid order (the executor's contract) and
tag every flow with a pipeline stage (model.STAGE_NAMES) so telemetry
attribution works unchanged. Each generator has a matching exact per-rank
traffic helper used by its lower bound in `core.lower_bounds`: the bound is
the port-occupancy argument (a rank's NIC send/recv port must carry all its
bytes at >= its own slowdown), computed with the same split arithmetic as
the generator so rounding never pushes the bound above the simulated time.
"""
from __future__ import annotations

import functools

import numpy as np

from repro.core.model import BandwidthProfile, Op, Schedule
from repro.core.ring import split_points
from repro.core.schedule import _FlowList, optcc_schedule


# ----------------------------------------------------------------------------
# double binary tree (dbtree)
# ----------------------------------------------------------------------------

def _balanced_tree(ranks: tuple[int, ...]) -> tuple[int, dict[int, list[int]]]:
    """Balanced BST over `ranks` (midpoint = root); returns (root, children)."""
    children: dict[int, list[int]] = {}

    def rec(lo: int, hi: int) -> int:
        mid = (lo + hi) // 2
        node = ranks[mid]
        ch = []
        if lo < mid:
            ch.append(rec(lo, mid - 1))
        if mid < hi:
            ch.append(rec(mid + 1, hi))
        children[node] = ch
        return node

    root = rec(0, len(ranks) - 1)
    return root, children


@functools.lru_cache(maxsize=128)
def _dbtree_shape(p: int) -> tuple[tuple[tuple[int, dict]], ...]:
    """The two trees for p ranks: tree 0 over (0..p-1), tree 1 over the
    rotated order (1..p-1, 0) so the interior/leaf roles differ between
    trees (a rank that is interior in one is near-leaf in the other)."""
    t0 = _balanced_tree(tuple(range(p)))
    t1 = _balanced_tree(tuple(range(1, p)) + (0,))
    return ((t0,), (t1,))


def _dbtree_trees(p: int) -> list[tuple[int, dict[int, list[int]]]]:
    return [shape[0] for shape in _dbtree_shape(p)]


@functools.lru_cache(maxsize=128)
def _dbtree_weights(p: int) -> np.ndarray:
    """(2, p) per-rank half-multiples: weights[t, r] halves of tree t's half
    cross rank r's NIC (n-independent, so the planner's closed-form dbtree
    bound/time evaluate as two cached vector scalings, not a Python walk)."""
    w = np.zeros((2, p))
    for t, (root, children) in enumerate(_dbtree_trees(p)):
        for node, ch in children.items():
            w[t, node] = len(ch) + (node != root)
    return w


def dbtree_traffic(p: int, n: int) -> np.ndarray:
    """Exact per-rank NIC traffic (send == recv by symmetry) of the double
    binary tree: per tree t, a non-root sends its half once (reduce) and
    receives it once (broadcast); a node with c children receives c halves
    (reduce) and sends c (broadcast). Segment rounding cancels because the
    k segments of a half sum to the half exactly."""
    halves = np.diff(split_points(n, 2)).astype(np.float64)
    return halves @ _dbtree_weights(p)


def dbtree_schedule(profile: BandwidthProfile, n: int, k: int = 16) -> Schedule:
    """Double-binary-tree AllReduce: reduce to each tree's root, then
    broadcast back down, pipelined over k segments per half. Per-rank FIFO
    send sequencing (like `core.ring`) keeps dispatch deterministic."""
    p = profile.p
    if p < 2:
        raise ValueError("need p >= 2")
    if profile.gpus_per_server != 1:
        raise ValueError("dbtree models one NIC per rank "
                         "(gpus_per_server == 1)")
    trees = _dbtree_trees(p)
    hs = split_points(n, 2)
    fl = _FlowList()
    last_send: dict[int, int] = {}

    def fifo(rank: int, deps: list[int]) -> list[int]:
        prev = last_send.get(rank)
        if prev is not None and prev not in deps:
            deps = deps + [prev]
        return deps

    for t, (root, children) in enumerate(trees):
        lo_t, hi_t = int(hs[t]), int(hs[t + 1])
        seg = np.round(np.linspace(lo_t, hi_t, k + 1)).astype(np.int64)
        # Post-order node list (children before parents).
        order: list[int] = []

        def post(node: int) -> None:
            for ch in children[node]:
                post(ch)
            order.append(node)

        post(root)
        parent = {ch: node for node, chs in children.items() for ch in chs}
        for m in range(k):
            lo, hi = int(seg[m]), int(seg[m + 1])
            key = ("dbt", t, m)
            recv_fids: dict[int, list[int]] = {r: [] for r in order}
            # Reduce: every non-root forwards its subtree sum to its parent
            # once its own children have delivered (post-order emission
            # keeps fids topological).
            for node in order:
                if node == root:
                    continue
                fid = fl.add(node, parent[node], hi - lo,
                             fifo(node, list(recv_fids[node])), lo, hi,
                             Op.ACCUM, key, stage="RS")
                recv_fids[parent[node]].append(fid)
                last_send[node] = fid
            # Root owns the total; zero-cost self-store writes its out[].
            done = fl.add(root, root, 0.0, list(recv_fids[root]), lo, hi,
                          Op.STORE, key, stage="SELF")
            # Broadcast: pre-order from the root.
            done_fid = {root: done}
            stack = [root]
            while stack:
                node = stack.pop()
                for ch in children[node]:
                    fid = fl.add(node, ch, hi - lo,
                                 fifo(node, [done_fid[node]]), lo, hi,
                                 Op.STORE, key, stage="AG")
                    done_fid[ch] = fid
                    last_send[node] = fid
                    stack.append(ch)
    return Schedule(profile=profile, n=n, nic_flows=fl.nic,
                    meta={"algo": "dbtree", "topology": "dbtree", "p": p,
                          "k": k, "stage_ids": fl.stage_ids()})


# ----------------------------------------------------------------------------
# 2-D torus (torus2d)
# ----------------------------------------------------------------------------

def torus_dims(p: int) -> tuple[int, int] | None:
    """(rows, cols) with rows the largest divisor <= sqrt(p); None when p
    has no 2-D factorization with both sides >= 2 (p prime or p < 4)."""
    r = 1
    d = 2
    while d * d <= p:
        if p % d == 0:
            r = d
        d += 1
    if r < 2:
        return None
    return r, p // r


def _torus_splits(p: int, n: int) -> tuple[np.ndarray, list[np.ndarray]]:
    r, c = torus_dims(p)
    col_pts = split_points(n, c)
    # One broadcast linspace over all c chunks (bit-identical to per-chunk
    # linspace calls, which made this O(c) numpy invocations and pushed the
    # p=1024 closed-form planning path past the 1 ms gate).
    grid = np.round(np.linspace(col_pts[:-1].astype(np.float64),
                                col_pts[1:].astype(np.float64),
                                r + 1, axis=1)).astype(np.int64)
    return col_pts, list(grid)


@functools.lru_cache(maxsize=128)
def _torus2d_phases(p: int, n: int) -> tuple:
    """The four (send, recv) per-rank traffic pairs, cached per (p, n):
    the planner's closed-form path evaluates them twice per plan (own
    lower bound + time model), and the <1 ms schedgen gate covers the
    torus too. Returned arrays are frozen read-only."""
    r, c = torus_dims(p)
    col_pts, sub_pts = _torus_splits(p, n)
    chunk = np.diff(col_pts).astype(np.float64)          # (c,)
    subs = np.diff(np.asarray(sub_pts), axis=1)          # (c, r)
    i = np.arange(r)[:, None]
    j = np.arange(c)[None, :]
    oj = (j + 1) % c                                     # chunk owned after A
    zero = np.zeros((r, c))
    phases = (
        # Row reduce-scatter: send all chunks but (j+1)%c, recv all but j.
        ((n - chunk[(j + 1) % c]) + zero, (n - chunk[j]) + zero),
        # Column reduce-scatter on chunk oj at subchunk granularity.
        (chunk[oj] - subs[oj, (i + 1) % r], chunk[oj] - subs[oj, i]),
        # Column allgather.
        (chunk[oj] - subs[oj, (i + 2) % r],
         chunk[oj] - subs[oj, (i + 1) % r]),
        # Row allgather: send all chunks but (j+2)%c, recv all but (j+1)%c.
        ((n - chunk[(j + 2) % c]) + zero, (n - chunk[(j + 1) % c]) + zero),
    )
    out = tuple((s.reshape(-1), v.reshape(-1)) for s, v in phases)
    for s, v in out:
        s.flags.writeable = False
        v.flags.writeable = False
    return out


@functools.lru_cache(maxsize=128)
def _torus2d_totals(p: int, n: int) -> tuple[np.ndarray, np.ndarray]:
    phases = _torus2d_phases(p, n)
    send = np.sum([s for s, _ in phases], axis=0)
    recv = np.sum([v for _, v in phases], axis=0)
    send.flags.writeable = False
    recv.flags.writeable = False
    return send, recv


def torus2d_traffic(p: int, n: int, per_phase: bool = False):
    """Exact per-rank (send, recv) NIC traffic of the 4-phase torus
    schedule, as flat arrays indexed by rank = i*c + j. Derived from the
    ring identities (a c-ring reduce-scatter sends every chunk except one),
    evaluated on the same integer split points the generator uses. With
    ``per_phase`` returns the list of four (send, recv) pairs instead of
    their sum. Arrays are cached and read-only; copy before mutating."""
    if per_phase:
        return list(_torus2d_phases(p, n))
    return _torus2d_totals(p, n)


def torus2d_schedule(profile: BandwidthProfile, n: int) -> Schedule:
    """2-D torus AllReduce (row RS -> column RS -> column AG -> row AG).

    The vector splits into c column chunks; chunk j splits into r
    subchunks keyed ("t2", j, s). Row-phase wire flows carry a whole chunk
    (main part + r-1 `extra` parts, one per subchunk) so buffers stay
    keyed at subchunk granularity for the column phases. After row RS,
    rank (i, j) owns the row-sum of chunk (j+1)%c; after column RS it owns
    the global sum of subchunk ((j+1)%c, (i+1)%r); the allgathers reverse
    both scatters. Per-rank FIFO send sequencing throughout."""
    p = profile.p
    dims = torus_dims(p)
    if dims is None:
        raise ValueError(f"p={p} has no 2-D torus factorization "
                         f"(needs a divisor pair >= 2x2)")
    if profile.gpus_per_server != 1:
        raise ValueError("torus2d models one NIC per rank "
                         "(gpus_per_server == 1)")
    r, c = dims
    col_pts, sub_pts = _torus_splits(p, n)

    def rank(i: int, j: int) -> int:
        return i * c + j

    fl = _FlowList()
    last_send: dict[int, int] = {}

    def fifo(rk: int, deps: list[int]) -> list[int]:
        prev = last_send.get(rk)
        if prev is not None and prev not in deps:
            deps = deps + [prev]
        return deps

    def chunk_parts(cj: int, op: Op) -> list[tuple[int, int, Op, tuple]]:
        return [(int(sub_pts[cj][s]), int(sub_pts[cj][s + 1]), op,
                 ("t2", cj, s)) for s in range(r)]

    # Phase A: row reduce-scatter (chunk granularity, subchunk parts).
    recv_a: dict[tuple[int, int], int] = {}   # (rank, chunk) -> arrival fid
    for t in range(c - 1):
        for i in range(r):
            for j in range(c):
                cj = (j - t) % c
                src, dst = rank(i, j), rank(i, (j + 1) % c)
                deps = [] if t == 0 else [recv_a[(src, cj)]]
                parts = chunk_parts(cj, Op.ACCUM)
                lo0, hi0, op0, key0 = parts[0]
                fid = fl.add(src, dst, int(col_pts[cj + 1] - col_pts[cj]),
                             fifo(src, deps), lo0, hi0, op0, key0,
                             extra=parts[1:], stage="RS")
                recv_a[(dst, cj)] = fid
                last_send[src] = fid

    # Phase B: column reduce-scatter of the owned chunk (j+1)%c.
    recv_b: dict[tuple[int, int], int] = {}   # (rank, subchunk) -> fid
    for t in range(r - 1):
        for j in range(c):
            oj = (j + 1) % c
            for i in range(r):
                s = (i - t) % r
                src, dst = rank(i, j), rank((i + 1) % r, j)
                deps = [recv_a[(src, oj)]] if t == 0 else [recv_b[(src, s)]]
                lo, hi = int(sub_pts[oj][s]), int(sub_pts[oj][s + 1])
                fid = fl.add(src, dst, hi - lo, fifo(src, deps), lo, hi,
                             Op.ACCUM, ("t2", oj, s), stage="RS")
                recv_b[(dst, s)] = fid
                last_send[src] = fid

    # Self-stores: rank (i, j) owns subchunk ((j+1)%c, (i+1)%r) globally.
    self_fid: dict[int, int] = {}
    for i in range(r):
        for j in range(c):
            oj, oi = (j + 1) % c, (i + 1) % r
            rk = rank(i, j)
            lo, hi = int(sub_pts[oj][oi]), int(sub_pts[oj][oi + 1])
            self_fid[rk] = fl.add(rk, rk, 0.0, [recv_b[(rk, oi)]], lo, hi,
                                  Op.STORE, ("t2", oj, oi), stage="SELF")

    # Phase C: column allgather of the owned chunk's subchunks.
    recv_c: dict[tuple[int, int], int] = {}
    last_c: dict[int, int] = {}
    for t in range(r - 1):
        for j in range(c):
            oj = (j + 1) % c
            for i in range(r):
                s = (i + 1 - t) % r
                src, dst = rank(i, j), rank((i + 1) % r, j)
                deps = [self_fid[src]] if t == 0 else [recv_c[(src, s)]]
                lo, hi = int(sub_pts[oj][s]), int(sub_pts[oj][s + 1])
                fid = fl.add(src, dst, hi - lo, fifo(src, deps), lo, hi,
                             Op.STORE, ("t2", oj, s), stage="AG")
                recv_c[(dst, s)] = fid
                last_c[dst] = fid
                last_send[src] = fid

    # Phase D: row allgather (chunk granularity, subchunk parts).
    recv_d: dict[tuple[int, int], int] = {}
    for t in range(c - 1):
        for i in range(r):
            for j in range(c):
                cj = (j + 1 - t) % c
                src, dst = rank(i, j), rank(i, (j + 1) % c)
                if t == 0:
                    # The full owned chunk is ready once the self-store and
                    # the last column-AG arrival (FIFO-ordered) are done.
                    deps = [self_fid[src]]
                    if src in last_c:
                        deps.append(last_c[src])
                else:
                    deps = [recv_d[(src, cj)]]
                parts = chunk_parts(cj, Op.STORE)
                lo0, hi0, op0, key0 = parts[0]
                fid = fl.add(src, dst, int(col_pts[cj + 1] - col_pts[cj]),
                             fifo(src, deps), lo0, hi0, op0, key0,
                             extra=parts[1:], stage="AG")
                recv_d[(dst, cj)] = fid
                last_send[src] = fid

    return Schedule(profile=profile, n=n, nic_flows=fl.nic,
                    meta={"algo": "torus2d", "topology": "torus2d", "p": p,
                          "rows": r, "cols": c, "stage_ids": fl.stage_ids()})


# ----------------------------------------------------------------------------
# hierarchical (NVLink reduce per server + OptCC across servers)
# ----------------------------------------------------------------------------

def server_slowdowns(profile: BandwidthProfile) -> tuple[float, ...]:
    """Per-server effective NIC slowdown: the max over the server's ranks
    (PXN pools every GPU on the box through the shared NICs)."""
    g = profile.gpus_per_server
    return tuple(max(profile.slowdown[s * g:(s + 1) * g])
                 for s in range(profile.num_servers))


def hierarchical_inner_profile(profile: BandwidthProfile) -> BandwidthProfile:
    """The server-level (one rank per server) profile the inter-server
    collective runs on."""
    return BandwidthProfile(p=profile.num_servers,
                            slowdown=server_slowdowns(profile),
                            gpus_per_server=1)


def hierarchical_schedule(profile: BandwidthProfile, n: int, k: int = 16,
                          fill_bubbles: bool = True) -> Schedule:
    """Intra-server NVLink reduce + inter-server OptCC over one lead/server.

    Per server, a NVLink ACCUM chain folds the g-1 non-lead GPUs into the
    lead's buffer for every inter-server transfer key; the inner schedule
    (`optcc_schedule` on the server-level profile, so ring when healthy and
    the straggler-aware OptCC otherwise) then runs unchanged between the
    leads, sending server sums instead of single-rank vectors; finally each
    inner STORE fans back out over NVLink to the server's other GPUs.
    Appendix-C bubble filling is disabled for the inner schedule: the fill
    fraction is calibrated for single-rank uploads, not server sums.

    ``fill_bubbles`` is accepted for planner-API uniformity and ignored.
    """
    del fill_bubbles
    g = profile.gpus_per_server
    if g < 2:
        raise ValueError("hierarchical needs gpus_per_server >= 2")
    q = profile.num_servers
    inner = optcc_schedule(hierarchical_inner_profile(profile), n, k,
                           fill_bubbles=False)
    inner_flows = sorted(inner.nic_flows, key=lambda f: f.fid)
    inner_stages = inner.meta.get("stage_ids")
    from repro.core.model import STAGE_NAMES

    def lead(s: int) -> int:
        return s * g

    def locals_of(s: int) -> list[int]:
        return list(range(s * g + 1, (s + 1) * g))

    # Distinct transfer keys (1:1 with [lo, hi) ranges), in first-use order.
    key_range: dict[tuple, tuple[int, int]] = {}
    for f in inner_flows:
        for lo, hi, _op, key in ((f.lo, f.hi, f.op, f.key), *f.extra):
            key_range.setdefault(key, (int(lo), int(hi)))

    fl = _FlowList()
    nv_last_send: dict[int, int] = {}

    def nv_fifo(rk: int, deps: list[int]) -> list[int]:
        prev = nv_last_send.get(rk)
        if prev is not None and prev not in deps:
            deps = deps + [prev]
        return deps

    # Phase 1: per-(server, key) NVLink collect chains into the lead.
    coll: list[dict[tuple, int]] = [dict() for _ in range(q)]
    for key, (lo, hi) in key_range.items():
        for s in range(q):
            nodes = locals_of(s) + [lead(s)]
            last = None
            for a, b in zip(nodes[:-1], nodes[1:]):
                deps = [] if last is None else [last]
                last = fl.add(a, b, hi - lo, nv_fifo(a, deps), lo, hi,
                              Op.ACCUM, key, nvlink=True, stage="N1")
                nv_last_send[a] = last
            coll[s][key] = last

    # Phase 2: the inner schedule, remapped onto the leads. Each flow
    # additionally depends on both endpoints' collects for its keys, so a
    # lead always forwards the *server* sum, never its raw vector.
    fmap: dict[int, int] = {}
    arrived: dict[tuple[int, tuple], int] = {}
    for f in inner_flows:
        deps = [fmap[d] for d in f.deps]
        for _lo, _hi, _op, key in ((f.lo, f.hi, f.op, f.key), *f.extra):
            for s in {f.src, f.dst}:
                cfid = coll[s][key]
                if cfid not in deps:
                    deps.append(cfid)
        stage = (STAGE_NAMES[int(inner_stages[f.fid])]
                 if inner_stages is not None else "SELF")
        nf = fl.add(lead(f.src), lead(f.dst), f.size, deps, f.lo, f.hi,
                    f.op, f.key, pri=f.pri, extra=f.extra, stage=stage)
        fmap[f.fid] = nf
        for lo, hi, op, key in ((f.lo, f.hi, f.op, f.key), *f.extra):
            if op is Op.STORE:
                arrived[(f.dst, key)] = nf

    missing = [(s, key) for s in range(q) for key in key_range
               if (s, key) not in arrived]
    assert not missing, f"inner schedule never stores {missing[:3]} ..."

    # Phase 3: NVLink distribute chains fan every stored key back out to
    # the server's non-lead GPUs.
    for (s, key), store_fid in arrived.items():
        lo, hi = key_range[key]
        nodes = [lead(s)] + locals_of(s)[::-1]
        prev = store_fid
        for a, b in zip(nodes[:-1], nodes[1:]):
            prev = fl.add(a, b, hi - lo, nv_fifo(a, [prev]), lo, hi,
                          Op.STORE, key, nvlink=True, stage="N2")
            nv_last_send[a] = prev

    return Schedule(profile=profile, n=n, nic_flows=fl.nic,
                    nvlink_flows=fl.nv,
                    meta={"algo": "hierarchical", "topology": "hierarchical",
                          "p": profile.p, "k": k, "g": g, "q": q,
                          "inner_algo": inner.meta.get("algo"),
                          "stage_ids": fl.stage_ids()})
