"""Deterministic discrete-event simulator for the bandwidth-bound flow model.

This is our stand-in for SimAI (the paper's NS-3-based simulator), restricted
to exactly the model in which the paper's theory lives (Section 3):

  * each rank has one NIC with a send port and a recv port; each port carries
    at most one flow at a time (the paper's non-overlap constraint, 4.1);
  * a NIC flow src->dst of `size` elements takes size * max(l_src, l_dst)
    time units (the slow endpoint throttles the wire);
  * NVLink flows (multi-GPU/server setting) use separate per-rank NVLink
    send/recv ports at (g-1)x the NIC rate and are never degraded;
  * flows start as soon as (a) all declared dependencies have completed and
    (b) both ports are free; among competing ready flows, the lower fid wins
    (fid encodes the schedule's priority order).

The same run always produces the same result (no randomness), matching the
paper's "SimAI is deterministic" setup.
"""
from __future__ import annotations

import heapq
from concurrent.futures import ProcessPoolExecutor
from concurrent.futures.process import BrokenProcessPool
from dataclasses import dataclass
from typing import Callable, Iterable, Sequence

from repro.core.model import BandwidthProfile, Flow, Schedule


@dataclass
class SimResult:
    makespan: float
    start: dict[int, float]
    finish: dict[int, float]
    # Per-port busy time, for utilization analysis: {(kind, rank, dir): time}
    port_busy: dict[tuple, float]

    def utilization(self, kind: str, rank: int, direction: str) -> float:
        if self.makespan == 0:
            return 0.0
        return self.port_busy.get((kind, rank, direction), 0.0) / self.makespan


def _flow_duration(flow: Flow, profile: BandwidthProfile, kind: str) -> float:
    if kind == "nv":
        assert profile.gpus_per_server > 1, \
            "NVLink flows require gpus_per_server > 1"
        return flow.size / profile.nvlink_rate
    return flow.size * max(profile.slowdown[flow.src], profile.slowdown[flow.dst])


def simulate(schedule: Schedule) -> SimResult:
    """Run the schedule to completion; returns makespan and per-flow times."""
    profile = schedule.profile
    flows: dict[int, tuple[Flow, str]] = {}
    for f in schedule.nic_flows:
        flows[f.fid] = (f, "nic")
    for f in schedule.nvlink_flows:
        if f.fid in flows:
            raise ValueError(f"duplicate fid {f.fid}")
        flows[f.fid] = (f, "nv")

    # Dependency bookkeeping.
    ndeps: dict[int, int] = {}
    dependents: dict[int, list[int]] = {}
    for fid, (f, _) in flows.items():
        cnt = 0
        for d in f.deps:
            if d not in flows:
                raise ValueError(f"flow {fid} depends on unknown fid {d}")
            cnt += 1
            dependents.setdefault(d, []).append(fid)
        ndeps[fid] = cnt

    # Ports: (kind, rank, "s"/"r") -> free?  plus waiting heaps per port.
    port_free: dict[tuple, bool] = {}
    waiting: dict[tuple, list[int]] = {}
    port_busy: dict[tuple, float] = {}

    def ports_of(fid: int) -> tuple[tuple, tuple]:
        f, kind = flows[fid]
        return (kind, f.src, "s"), (kind, f.dst, "r")

    for fid in flows:
        for port in ports_of(fid):
            port_free.setdefault(port, True)
            waiting.setdefault(port, [])

    started: set[int] = set()
    finished: set[int] = set()
    woken: set[int] = set()
    start_t: dict[int, float] = {}
    finish_t: dict[int, float] = {}
    # (time, seq, fid, is_wake); wake events re-attempt releases.
    events: list[tuple[float, int, int, bool]] = []
    seq = 0
    now = 0.0

    def push_event(t: float, fid: int, is_wake: bool) -> None:
        nonlocal seq
        heapq.heappush(events, (t, seq, fid, is_wake))
        seq += 1

    def try_start(fid: int) -> bool:
        if fid in started:
            return True
        f, kind = flows[fid]
        if f.release > now:
            if fid not in woken:
                woken.add(fid)
                push_event(f.release, fid, True)
            return False
        sp, rp = ports_of(fid)
        if not (port_free[sp] and port_free[rp]):
            return False
        port_free[sp] = port_free[rp] = False
        started.add(fid)
        dur = _flow_duration(f, profile, kind)
        start_t[fid] = now
        finish_t[fid] = now + dur
        port_busy[sp] = port_busy.get(sp, 0.0) + dur
        port_busy[rp] = port_busy.get(rp, 0.0) + dur
        push_event(now + dur, fid, False)
        return True

    def prio(fid: int) -> tuple[float, int]:
        return flows[fid][0].priority

    def enqueue_ready(fid: int) -> None:
        # Try to start immediately; if blocked, wait on both ports.
        if try_start(fid):
            return
        sp, rp = ports_of(fid)
        heapq.heappush(waiting[sp], (prio(fid), fid))
        heapq.heappush(waiting[rp], (prio(fid), fid))

    for fid in sorted(flows, key=prio):
        if ndeps[fid] == 0:
            enqueue_ready(fid)

    while events:
        now, done_batch, wake_batch = events[0][0], [], []
        # Pop every event at `now` (simultaneous completions/wakes).
        while events and events[0][0] == now:
            _, _, fid, is_wake = heapq.heappop(events)
            (wake_batch if is_wake else done_batch).append(fid)
        newly_ready: list[int] = []
        freed_ports: list[tuple] = []
        for fid in done_batch:
            finished.add(fid)
            sp, rp = ports_of(fid)
            port_free[sp] = port_free[rp] = True
            freed_ports.extend((sp, rp))
            for dep in dependents.get(fid, ()):  # release dependents
                ndeps[dep] -= 1
                if ndeps[dep] == 0:
                    newly_ready.append(dep)
        for fid in wake_batch:
            if fid not in started and ndeps[fid] == 0:
                woken.discard(fid)
                try_start(fid)
        for fid in sorted(newly_ready, key=prio):
            enqueue_ready(fid)
        # Freed ports may admit waiting flows. Admission is work-conserving:
        # if the highest-priority waiter is blocked on its *other* port we
        # try lower-priority waiters (this is what packs bubble-filling
        # flows into straggler-link gaps). Entries for already-started flows
        # are skipped lazily.
        for port in freed_ports:
            q = waiting[port]
            blocked: list[tuple] = []
            while q and port_free[port]:
                entry = heapq.heappop(q)
                cand = entry[1]
                if cand in started:
                    continue
                if not try_start(cand):
                    blocked.append(entry)
            for entry in blocked:
                heapq.heappush(q, entry)

    if len(finished) != len(flows):
        stuck = [fid for fid in flows if fid not in finished]
        raise RuntimeError(
            f"deadlock: {len(stuck)}/{len(flows)} flows never ran, e.g. "
            f"{sorted(stuck)[:5]}")
    makespan = max(finish_t.values(), default=0.0)
    return SimResult(makespan=makespan, start=start_t, finish=finish_t,
                     port_busy=port_busy)


def simulate_many(schedules: Sequence[Schedule] | Iterable[Schedule],
                  workers: int = 0) -> list[SimResult]:
    """Simulate a batch of schedules, preserving input order.

    workers == 0 runs serially in-process; workers > 0 fans the batch out
    over a process pool (schedules are pickled to the workers, so this pays
    off only when per-schedule simulation dominates serialization — large
    flow graphs). Results are identical either way: the simulator is
    deterministic and each schedule is independent.
    """
    return map_scenarios(simulate, list(schedules), workers=workers)


def map_scenarios(fn: Callable, items: Sequence, workers: int = 0) -> list:
    """Order-preserving map used by the sweep engine: `fn` must be a
    module-level picklable callable. workers == 0 -> serial; the serial path
    is also the fallback when a pool cannot be spawned (sandboxes without
    /dev/shm or fork support)."""
    items = list(items)
    if workers <= 0 or len(items) <= 1:
        return [fn(x) for x in items]
    try:
        with ProcessPoolExecutor(max_workers=workers) as pool:
            return list(pool.map(fn, items, chunksize=max(1, len(items) // (8 * workers))))
    except (OSError, BrokenProcessPool):
        # Pool creation failed, or workers were killed mid-map (seccomp,
        # rlimits). fn is pure/deterministic, so re-running serially is safe.
        return [fn(x) for x in items]
