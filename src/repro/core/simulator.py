"""Deterministic discrete-event simulator for the bandwidth-bound flow model.

This is our stand-in for SimAI (the paper's NS-3-based simulator), restricted
to exactly the model in which the paper's theory lives (Section 3):

  * each rank has one NIC with a send port and a recv port; each port carries
    at most one flow at a time (the paper's non-overlap constraint, 4.1);
  * a NIC flow src->dst of `size` elements takes size * max(l_src, l_dst)
    time units (the slow endpoint throttles the wire);
  * NVLink flows (multi-GPU/server setting) use separate per-rank NVLink
    send/recv ports at (g-1)x the NIC rate and are never degraded;
  * zero-size flows are local bookkeeping (self-stores), not wire traffic:
    they complete the moment their dependencies do and never occupy a port;
  * flows start as soon as (a) all declared dependencies have completed and
    (b) both ports are free; among competing ready flows, the lower fid wins
    (fid encodes the schedule's priority order);
  * schedules tagged ``meta["port_inorder"]`` (the slotted OptCC
    construction) serve every port strictly in (pri, fid) order - a NIC
    executing its transmit queue in schedule order - instead of the greedy
    opportunistic dispatch arbitrary dependency graphs get.

The same run always produces the same result (no randomness), matching the
paper's "SimAI is deterministic" setup.

Two implementations produce bit-identical results (enforced by
tests/test_vectorized_equivalence.py):

  * `simulate_reference` - the scalar event loop below, the semantics oracle;
  * the vectorized fast path in `core.flowvec` for schedules whose meta
    carries ``vec_exact: True`` (ring with FIFO sequencing, slotted OptCC):
    for those graphs port service order is forced, so completion times are
    the least fixed point of a max-plus recurrence evaluated in numpy blocks.

`simulate` dispatches to the fast path when it is provably exact and falls
back to the event loop for arbitrary dependency graphs (legacy/multi/
multi-GPU schedules, hand-built tests).
"""
from __future__ import annotations

import heapq
import os
from concurrent.futures import ProcessPoolExecutor
from concurrent.futures.process import BrokenProcessPool
from typing import Callable, Iterable, Optional, Sequence

import numpy as np

from repro.core.model import (BandwidthProfile, FaultTimeline, Flow,
                              Schedule)


class SimResult:
    """Simulation outcome. `start`/`finish`/`port_busy` are materialized
    lazily: the sweep hot path only reads `makespan`, and building
    100k-entry dicts per scenario would dominate the vectorized fast path.
    """

    __slots__ = ("makespan", "_start", "_finish", "_port_busy", "_lazy",
                 "telemetry")

    def __init__(self, makespan: float,
                 start: Optional[dict] = None,
                 finish: Optional[dict] = None,
                 port_busy: Optional[dict] = None,
                 lazy: Optional[Callable[[], tuple]] = None,
                 telemetry=None):
        self.makespan = makespan
        self._start = start
        self._finish = finish
        self._port_busy = port_busy
        self._lazy = lazy
        # repro.obs.FlowTelemetry when the run was asked for it, else None.
        # Attached post-hoc by `simulate(..., telemetry=True)`; never read
        # (or written) by any timing path.
        self.telemetry = telemetry

    def _materialize(self) -> None:
        if self._lazy is not None:
            self._start, self._finish, self._port_busy = self._lazy()
            self._lazy = None

    @property
    def start(self) -> dict[int, float]:
        self._materialize()
        return self._start

    @property
    def finish(self) -> dict[int, float]:
        self._materialize()
        return self._finish

    @property
    def port_busy(self) -> dict[tuple, float]:
        # {(kind, rank, dir): time}, for utilization analysis
        self._materialize()
        return self._port_busy

    def utilization(self, kind: str, rank: int, direction: str) -> float:
        if self.makespan == 0:
            return 0.0
        return self.port_busy.get((kind, rank, direction), 0.0) / self.makespan

    def __reduce__(self):
        # Closures don't pickle; materialize before crossing process
        # boundaries (simulate_many with workers > 0).
        return (SimResult,
                (self.makespan, self.start, self.finish, self.port_busy,
                 None, self.telemetry))


def _flow_duration(flow: Flow, profile: BandwidthProfile, kind: str) -> float:
    if kind == "nv":
        assert profile.gpus_per_server > 1, \
            "NVLink flows require gpus_per_server > 1"
        return flow.size / profile.nvlink_rate
    return flow.size * max(profile.slowdown[flow.src], profile.slowdown[flow.dst])


def _attach_telemetry(schedule: Schedule, result: "SimResult") -> "SimResult":
    """Derive per-flow telemetry from an already-finished run (opt-in).

    Post-hoc by design: the timings in `result` were produced by exactly
    the same code path telemetry-off runs use, so enabling telemetry cannot
    perturb a single bit of any simulated time.
    """
    from repro import obs      # deliberate late import: obs is opt-in
    result.telemetry = obs.collect(schedule, result)
    return result


def simulate(schedule: Schedule, telemetry: bool = False,
             timeline: Optional[FaultTimeline] = None) -> SimResult:
    """Run the schedule to completion; returns makespan and per-flow times.

    Dispatches to the vectorized fast path when the schedule certifies it is
    exact for its structure (``meta["vec_exact"]``), else runs the scalar
    reference event loop. Both paths agree bit-for-bit on eligible
    schedules (tests/test_vectorized_equivalence.py).

    With ``timeline=`` the run honors a `FaultTimeline`: per-rank NIC rates
    are piecewise-constant in time, in-flight flows are re-timed at every
    breakpoint (remaining elements carry over at the new rate), and flows
    starting after a breakpoint use the rates then in force. A timeline
    whose effective slowdown vector never changes after t=0 degenerates to
    the static run of `timeline.profile_at(schedule.profile, 0)` -
    bit-for-bit, because the timeline machinery is skipped entirely. The
    `vec_exact` fast path stays exact under timelines: forced port order is
    a structural property, so only the finish arithmetic changes (a
    segmented pass mirroring the event loops op-for-op; equality pinned by
    tests/test_replay.py).

    With ``telemetry=True`` the result additionally carries a
    `repro.obs.FlowTelemetry` (``result.telemetry``) derived from the same
    start/finish times - timings are identical either way.

    With ``REPRO_DEBUG`` set in the environment, the schedule's meta is
    checked against the documented key contract
    (`model.validate_schedule_meta`) before simulating.
    """
    if os.environ.get("REPRO_DEBUG"):
        from repro.core.model import validate_schedule_meta
        validate_schedule_meta(schedule)
    if schedule.meta.get("vec_exact"):
        from repro.core import flowvec
        res = flowvec.simulate_arrays(schedule, timeline=timeline)
    else:
        res = _simulate_greedy_fast(schedule, timeline=timeline)
    return _attach_telemetry(schedule, res) if telemetry else res


def _simulate_greedy_fast(schedule: Schedule,
                          timeline: Optional[FaultTimeline] = None
                          ) -> SimResult:
    """Greedy event loop over columnar arrays: identical semantics and
    results to `simulate_reference`, ~3x faster (int ports, precomputed
    durations and priorities, no per-flow dataclass traffic). Used for the
    schedules whose dispatch is genuinely dynamic (multi-straggler,
    multi-GPU, hand-built graphs); bit-equality with the reference loop is
    enforced by tests/test_vectorized_equivalence.py (static) and
    tests/test_replay.py (timelines).

    Timeline semantics: at each breakpoint every in-flight NIC wire flow is
    re-timed - remaining elements = rem - elapsed/l_old, new finish =
    now + rem * l_new - and its queued finish event goes stale (skipped on
    pop via a finish-time match). NVLink flows are never degraded and are
    never re-timed; zero-size flows hold no ports and finish instantly.
    """
    from repro.core import flowvec

    fa = schedule.arrays if schedule.arrays is not None \
        else flowvec.FlowArrays.from_schedule(schedule)
    n = fa.nflows
    if n == 0:
        return SimResult(0.0, {}, {}, {})
    profile = schedule.profile
    if fa.nv.any():
        assert profile.gpus_per_server > 1, \
            "NVLink flows require gpus_per_server > 1"
    tl_breaks: tuple = ()
    if timeline is not None:
        tl_breaks, tl_vecs = timeline.segments(profile)
        sl = np.asarray(tl_vecs[0], np.float64)
    else:
        sl = np.asarray(profile.slowdown, np.float64)
    tl_on = bool(tl_breaks)
    dur_a = fa.size * np.maximum(sl[fa.src], sl[fa.dst])
    if fa.nv.any():
        dur_a[fa.nv] = fa.size[fa.nv] / profile.nvlink_rate
    nv4 = fa.nv.astype(np.int64)
    # Hot per-element access wants plain Python lists, not numpy scalars.
    dur = dur_a.tolist()
    size = fa.size.tolist()
    release = fa.release.tolist()
    sport = (fa.src * 4 + nv4 * 2).tolist()
    rport = (fa.dst * 4 + nv4 * 2 + 1).tolist()
    # Fast-heap mode: with no priorities and no releases (multi/multi-GPU
    # and most hand-built graphs), (pri, fid) order *is* fid order, so the
    # waiting heaps can hold plain ints and release wake-ups never happen.
    simple = bool(np.isnan(fa.pri).all()) and not fa.release.any()
    pri_key = np.where(np.isnan(fa.pri), np.arange(n, dtype=np.float64),
                       fa.pri).tolist()
    dep_counts = np.diff(fa.dep_indptr)
    ndeps = dep_counts.tolist()
    nports = 4 * profile.p
    # Reverse adjacency (dependents) as CSR, built vectorized: group dep
    # edges by their target fid, keeping each edge's owning row.
    nnz = len(fa.dep_indices)
    if nnz:
        if (fa.dep_indices < 0).any() or (fa.dep_indices >= n).any():
            bad = fa.dep_indices[(fa.dep_indices < 0)
                                 | (fa.dep_indices >= n)][0]
            raise ValueError(f"flow depends on unknown fid {int(bad)}")
        rows = np.repeat(np.arange(n, dtype=np.int64), dep_counts)
        grp = np.argsort(fa.dep_indices, kind="stable")
        dep_rows = rows[grp].tolist()
        dptr = np.zeros(n + 1, np.int64)
        np.cumsum(np.bincount(fa.dep_indices, minlength=n), out=dptr[1:])
        dptr = dptr.tolist()
    else:
        dep_rows = []
        dptr = [0] * (n + 1)

    # Strict in-order port service (slotted schedules). Statically the
    # slotted layout is collision-free, so greedy dispatch coincides with
    # in-order service and this never triggers - but under a timeline the
    # rates shift mid-run and opportunistic dispatch would deviate from the
    # reference loop, so the check must be real here too.
    inorder = bool(schedule.meta.get("port_inorder"))
    port_head = [0] * nports
    port_seq: list[list[int]] = [[] for _ in range(nports)]
    if inorder:
        for fid in sorted(range(n), key=lambda i: (pri_key[i], i)):
            if size[fid] > 0:
                port_seq[sport[fid]].append(fid)
                port_seq[rport[fid]].append(fid)

    port_free = [True] * nports
    waiting: list[list] = [[] for _ in range(nports)]
    port_busy = [0.0] * nports
    started = [False] * n
    woken = [False] * n
    start_t = [0.0] * n
    finish_t = [0.0] * n
    # Event kinds: 0 = flow finish, 1 = release wake-up, 2 = rate change
    # (fid then indexes the timeline breakpoint).
    events: list[tuple[float, int, int, int]] = []
    seq = 0
    now = 0.0
    nfinished = 0
    push = heapq.heappush
    pop = heapq.heappop

    if tl_on:
        # Per-segment effective slowdown per flow (NIC wire flows only) +
        # in-flight re-timing state. `fdone` guards against stale finish
        # events re-finishing a re-timed flow.
        lmax_segs = [np.maximum(np.asarray(v, np.float64)[fa.src],
                                np.asarray(v, np.float64)[fa.dst]).tolist()
                     for v in tl_vecs]
        nicw = ((fa.size > 0) & ~fa.nv).tolist()
        rem = [0.0] * n
        tbase = [0.0] * n
        lcur = [0.0] * n
        fdone = [False] * n
        inflight: set[int] = set()
        seg_idx = 0
        for j, bt in enumerate(tl_breaks):
            push(events, (bt, seq, j, 2))
            seq += 1

    def try_start(fid: int) -> bool:
        nonlocal seq
        if started[fid]:
            return True
        if not simple and release[fid] > now:
            if not woken[fid]:
                woken[fid] = True
                push(events, (release[fid], seq, fid, 1))
                seq += 1
            return False
        if size[fid] <= 0:
            started[fid] = True
            start_t[fid] = finish_t[fid] = now
            push(events, (now, seq, fid, 0))
            seq += 1
            return True
        sp, rp = sport[fid], rport[fid]
        if not (port_free[sp] and port_free[rp]):
            return False
        if inorder and (port_seq[sp][port_head[sp]] != fid
                        or port_seq[rp][port_head[rp]] != fid):
            return False
        port_free[sp] = port_free[rp] = False
        if inorder:
            port_head[sp] += 1
            port_head[rp] += 1
        started[fid] = True
        if tl_on and nicw[fid]:
            l = lmax_segs[seg_idx][fid]
            d = size[fid] * l
            rem[fid] = size[fid]
            tbase[fid] = now
            lcur[fid] = l
            inflight.add(fid)
        else:
            d = dur[fid]
        start_t[fid] = now
        finish_t[fid] = now + d
        port_busy[sp] += d
        port_busy[rp] += d
        push(events, (now + d, seq, fid, 0))
        seq += 1
        return True

    def enqueue_ready(fid: int) -> None:
        if try_start(fid):
            return
        entry = fid if simple else (pri_key[fid], fid)
        push(waiting[sport[fid]], entry)
        push(waiting[rport[fid]], entry)

    if simple:
        order0 = range(n)
    else:
        order0 = sorted(range(n), key=lambda i: (pri_key[i], i))
    for fid in order0:
        if ndeps[fid] == 0:
            enqueue_ready(fid)

    while events:
        now = events[0][0]
        done_batch: list[int] = []
        wake_batch: list[int] = []
        rate_batch: list[int] = []
        while events and events[0][0] == now:
            _, _, fid, kind = pop(events)
            if kind == 0:
                if tl_on:
                    if fdone[fid] or finish_t[fid] != now:
                        continue        # stale event from before a re-time
                    fdone[fid] = True
                done_batch.append(fid)
            elif kind == 1:
                wake_batch.append(fid)
            else:
                rate_batch.append(fid)
        newly_ready: list[int] = []
        freed_ports: list[int] = []
        for fid in done_batch:
            nfinished += 1
            if tl_on:
                inflight.discard(fid)
            if size[fid] > 0:
                sp, rp = sport[fid], rport[fid]
                port_free[sp] = port_free[rp] = True
                freed_ports.append(sp)
                freed_ports.append(rp)
            for j in range(dptr[fid], dptr[fid + 1]):
                dep = dep_rows[j]
                ndeps[dep] -= 1
                if ndeps[dep] == 0:
                    newly_ready.append(dep)
        for bidx in rate_batch:
            # Rates change at `now` *after* flows finishing exactly at `now`
            # complete (zero remaining work) and *before* any flow starts at
            # `now` (new arrivals see the new rates). Every in-flight NIC
            # wire flow is re-timed with the carried-over remainder; the
            # same arithmetic, in the same order, as flowvec's segmented
            # pass - that is what keeps vec and scalar runs bit-identical.
            seg_idx = bidx + 1
            lm = lmax_segs[seg_idx]
            for fid in sorted(inflight):
                r = max(rem[fid] - (now - tbase[fid]) / lcur[fid], 0.0)
                l_new = lm[fid]
                rem[fid] = r
                tbase[fid] = now
                lcur[fid] = l_new
                newf = now + r * l_new
                if newf != finish_t[fid]:
                    delta = newf - finish_t[fid]
                    port_busy[sport[fid]] += delta
                    port_busy[rport[fid]] += delta
                    finish_t[fid] = newf
                    push(events, (newf, seq, fid, 0))
                    seq += 1
        for fid in wake_batch:
            if not started[fid] and ndeps[fid] == 0:
                woken[fid] = False
                try_start(fid)
        if newly_ready:
            if simple:
                newly_ready.sort()
            else:
                newly_ready.sort(key=lambda i: (pri_key[i], i))
            for fid in newly_ready:
                enqueue_ready(fid)
        for port in freed_ports:
            q = waiting[port]
            blocked: list = []
            while q and port_free[port]:
                entry = pop(q)
                cand = entry if simple else entry[1]
                if started[cand]:
                    continue
                if not try_start(cand):
                    blocked.append(entry)
            for entry in blocked:
                push(q, entry)

    if nfinished != n:
        stuck = [fid for fid in range(n)
                 if ndeps[fid] > 0 or not started[fid]]
        raise RuntimeError(
            f"deadlock: {len(stuck)}/{n} flows never ran, e.g. "
            f"{sorted(stuck)[:5]}")
    makespan = max(finish_t) if n else 0.0

    def materialize():
        start_d = dict(enumerate(start_t))
        finish_d = dict(enumerate(finish_t))
        busy: dict[tuple, float] = {}
        for pid, b in enumerate(port_busy):
            if b > 0.0:
                kind = "nv" if pid & 2 else "nic"
                busy[(kind, pid // 4, "r" if pid & 1 else "s")] = b
        return start_d, finish_d, busy

    return SimResult(makespan, lazy=materialize)


def simulate_reference(schedule: Schedule,
                       telemetry: bool = False,
                       timeline: Optional[FaultTimeline] = None) -> SimResult:
    """Scalar discrete-event loop: the semantics oracle for `simulate`.

    Honors a `FaultTimeline` with the same semantics as the fast paths
    (piecewise-constant NIC rates; in-flight flows carry their remaining
    elements across breakpoints at the new rate); tests/test_replay.py pins
    bit-equality against both.
    """
    profile = schedule.profile
    tl_breaks: tuple = ()
    if timeline is not None:
        tl_breaks, tl_vecs = timeline.segments(profile)
        sl = list(tl_vecs[0])
    else:
        sl = list(profile.slowdown)
    tl_on = bool(tl_breaks)
    flows: dict[int, tuple[Flow, str]] = {}
    for f in schedule.nic_flows:
        flows[f.fid] = (f, "nic")
    for f in schedule.nvlink_flows:
        if f.fid in flows:
            raise ValueError(f"duplicate fid {f.fid}")
        flows[f.fid] = (f, "nv")

    # Dependency bookkeeping.
    ndeps: dict[int, int] = {}
    dependents: dict[int, list[int]] = {}
    for fid, (f, _) in flows.items():
        cnt = 0
        for d in f.deps:
            if d not in flows:
                raise ValueError(f"flow {fid} depends on unknown fid {d}")
            cnt += 1
            dependents.setdefault(d, []).append(fid)
        ndeps[fid] = cnt

    # Ports: (kind, rank, "s"/"r") -> free?  plus waiting heaps per port.
    port_free: dict[tuple, bool] = {}
    waiting: dict[tuple, list[int]] = {}
    port_busy: dict[tuple, float] = {}

    def ports_of(fid: int) -> tuple[tuple, tuple]:
        f, kind = flows[fid]
        return (kind, f.src, "s"), (kind, f.dst, "r")

    for fid in flows:
        for port in ports_of(fid):
            port_free.setdefault(port, True)
            waiting.setdefault(port, [])

    def prio(fid: int) -> tuple[float, int]:
        return flows[fid][0].priority

    # Strict in-order port service (slotted schedules): each port's wire
    # flows may only start in (pri, fid) order - the NIC drains its transmit
    # queue in schedule order instead of opportunistically.
    inorder = bool(schedule.meta.get("port_inorder"))
    port_head: dict[tuple, int] = {}
    port_seq: dict[tuple, list[int]] = {}
    if inorder:
        for fid in sorted(flows, key=prio):
            if flows[fid][0].size <= 0:
                continue
            for port in ports_of(fid):
                port_seq.setdefault(port, []).append(fid)
        port_head = {port: 0 for port in port_seq}

    started: set[int] = set()
    finished: set[int] = set()
    woken: set[int] = set()
    start_t: dict[int, float] = {}
    finish_t: dict[int, float] = {}
    # (time, seq, fid, kind); kind 0 = finish, 1 = release wake-up,
    # 2 = rate change (fid indexes the timeline breakpoint).
    events: list[tuple[float, int, int, int]] = []
    seq = 0
    now = 0.0

    # Timeline re-timing state (NIC wire flows in flight only).
    seg_idx = 0
    rem: dict[int, float] = {}
    tbase: dict[int, float] = {}
    lcur: dict[int, float] = {}
    inflight: set[int] = set()

    def push_event(t: float, fid: int, kind: int) -> None:
        nonlocal seq
        heapq.heappush(events, (t, seq, fid, kind))
        seq += 1

    if tl_on:
        for j, bt in enumerate(tl_breaks):
            push_event(bt, j, 2)

    def try_start(fid: int) -> bool:
        if fid in started:
            return True
        f, kind = flows[fid]
        if f.release > now:
            if fid not in woken:
                woken.add(fid)
                push_event(f.release, fid, 1)
            return False
        if f.size <= 0:
            # Bookkeeping flow (self-store): no wire traffic, no ports.
            started.add(fid)
            start_t[fid] = finish_t[fid] = now
            push_event(now, fid, 0)
            return True
        sp, rp = ports_of(fid)
        if not (port_free[sp] and port_free[rp]):
            return False
        if inorder and (port_seq[sp][port_head[sp]] != fid
                        or port_seq[rp][port_head[rp]] != fid):
            return False
        port_free[sp] = port_free[rp] = False
        if inorder:
            port_head[sp] += 1
            port_head[rp] += 1
        started.add(fid)
        if kind == "nv":
            dur = f.size / profile.nvlink_rate
        else:
            l = max(sl[f.src], sl[f.dst])
            dur = f.size * l
            if tl_on:
                rem[fid] = f.size
                tbase[fid] = now
                lcur[fid] = l
                inflight.add(fid)
        start_t[fid] = now
        finish_t[fid] = now + dur
        port_busy[sp] = port_busy.get(sp, 0.0) + dur
        port_busy[rp] = port_busy.get(rp, 0.0) + dur
        push_event(now + dur, fid, 0)
        return True

    def enqueue_ready(fid: int) -> None:
        # Try to start immediately; if blocked, wait on both ports.
        if try_start(fid):
            return
        sp, rp = ports_of(fid)
        heapq.heappush(waiting[sp], (prio(fid), fid))
        heapq.heappush(waiting[rp], (prio(fid), fid))

    for fid in sorted(flows, key=prio):
        if ndeps[fid] == 0:
            enqueue_ready(fid)

    while events:
        now = events[0][0]
        done_batch: list[int] = []
        wake_batch: list[int] = []
        rate_batch: list[int] = []
        # Pop every event at `now` (simultaneous completions/wakes/rates).
        while events and events[0][0] == now:
            _, _, fid, kind = heapq.heappop(events)
            if kind == 0:
                if tl_on:
                    if fid in finished or finish_t.get(fid) != now:
                        continue        # stale event from before a re-time
                done_batch.append(fid)
            elif kind == 1:
                wake_batch.append(fid)
            else:
                rate_batch.append(fid)
        newly_ready: list[int] = []
        freed_ports: list[tuple] = []
        for fid in done_batch:
            finished.add(fid)
            if tl_on:
                inflight.discard(fid)
            if flows[fid][0].size > 0:       # zero flows never held ports
                sp, rp = ports_of(fid)
                port_free[sp] = port_free[rp] = True
                freed_ports.extend((sp, rp))
            for dep in dependents.get(fid, ()):  # release dependents
                ndeps[dep] -= 1
                if ndeps[dep] == 0:
                    newly_ready.append(dep)
        for bidx in rate_batch:
            # Rates change at `now` *after* flows finishing exactly at `now`
            # complete and *before* any flow starts at `now` — identical
            # ordering and arithmetic to _simulate_greedy_fast / flowvec so
            # all three paths stay bit-identical.
            seg_idx = bidx + 1
            sl = list(tl_vecs[seg_idx])
            for fid in sorted(inflight):
                f = flows[fid][0]
                r = max(rem[fid] - (now - tbase[fid]) / lcur[fid], 0.0)
                l_new = max(sl[f.src], sl[f.dst])
                rem[fid] = r
                tbase[fid] = now
                lcur[fid] = l_new
                newf = now + r * l_new
                if newf != finish_t[fid]:
                    delta = newf - finish_t[fid]
                    sp, rp = ports_of(fid)
                    port_busy[sp] += delta
                    port_busy[rp] += delta
                    finish_t[fid] = newf
                    push_event(newf, fid, 0)
        for fid in wake_batch:
            if fid not in started and ndeps[fid] == 0:
                woken.discard(fid)
                try_start(fid)
        for fid in sorted(newly_ready, key=prio):
            enqueue_ready(fid)
        # Freed ports may admit waiting flows. Admission is work-conserving:
        # if the highest-priority waiter is blocked on its *other* port we
        # try lower-priority waiters (this is what packs bubble-filling
        # flows into straggler-link gaps). Entries for already-started flows
        # are skipped lazily.
        for port in freed_ports:
            q = waiting[port]
            blocked: list[tuple] = []
            while q and port_free[port]:
                entry = heapq.heappop(q)
                cand = entry[1]
                if cand in started:
                    continue
                if not try_start(cand):
                    blocked.append(entry)
            for entry in blocked:
                heapq.heappush(q, entry)

    if len(finished) != len(flows):
        stuck = [fid for fid in flows if fid not in finished]
        raise RuntimeError(
            f"deadlock: {len(stuck)}/{len(flows)} flows never ran, e.g. "
            f"{sorted(stuck)[:5]}")
    makespan = max(finish_t.values(), default=0.0)
    res = SimResult(makespan=makespan, start=start_t, finish=finish_t,
                    port_busy=port_busy)
    return _attach_telemetry(schedule, res) if telemetry else res


def simulate_many(schedules: Sequence[Schedule] | Iterable[Schedule],
                  workers: int = 0) -> list[SimResult]:
    """Simulate a batch of schedules, preserving input order.

    workers == 0 runs serially in-process; workers > 0 fans the batch out
    over a process pool (schedules are pickled to the workers, so this pays
    off only when per-schedule simulation dominates serialization — large
    flow graphs). Results are identical either way: the simulator is
    deterministic and each schedule is independent.
    """
    return map_scenarios(simulate, list(schedules), workers=workers)


def map_scenarios(fn: Callable, items: Sequence, workers: int = 0) -> list:
    """Order-preserving map used by the sweep engine: `fn` must be a
    module-level picklable callable. workers == 0 -> serial; the serial path
    is also the fallback when a pool cannot be spawned (sandboxes without
    /dev/shm or fork support)."""
    items = list(items)
    if workers <= 0 or len(items) <= 1:
        return [fn(x) for x in items]
    try:
        with ProcessPoolExecutor(max_workers=workers) as pool:
            return list(pool.map(fn, items, chunksize=max(1, len(items) // (8 * workers))))
    except (OSError, BrokenProcessPool):
        # Pool creation failed, or workers were killed mid-map (seccomp,
        # rlimits). fn is pure/deterministic, so re-running serially is safe.
        return [fn(x) for x in items]
