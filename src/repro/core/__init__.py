"""OptCC core: the paper's contribution as a reusable library.

Public API:
  BandwidthProfile, Flow, Op, Schedule       - flow model (core.model)
  simulate, simulate_many, SimResult         - bandwidth simulator
  execute, verify_allreduce                  - data-level verification
  ring_allreduce_schedule                    - NCCL ring / ICCL baseline
  optcc_schedule                             - OptCC (all three settings)
  make_plan, Plan                            - online planner
  lower_bounds                               - Theorems 1,2,3,6,13 + times
"""
from repro.core import lower_bounds
from repro.core.baselines import (iccl_time_asymptotic, iccl_time_simulated,
                                  nccl_no_failure_time, r2ccl_time)
from repro.core.executor import execute, verify_allreduce
from repro.core.model import BandwidthProfile, Flow, Op, Schedule
from repro.core.planner import Plan, make_plan
from repro.core.ring import ring_allreduce_schedule
from repro.core.schedule import (optcc_multi_gpu_schedule,
                                 optcc_multi_schedule, optcc_schedule,
                                 optcc_single_schedule)
from repro.core.simulator import SimResult, simulate, simulate_many

__all__ = [
    "BandwidthProfile", "Flow", "Op", "Schedule", "SimResult", "simulate",
    "simulate_many",
    "execute", "verify_allreduce", "ring_allreduce_schedule",
    "optcc_schedule", "optcc_single_schedule", "optcc_multi_schedule",
    "optcc_multi_gpu_schedule", "make_plan", "Plan", "lower_bounds",
    "nccl_no_failure_time", "iccl_time_asymptotic", "iccl_time_simulated",
    "r2ccl_time",
]
