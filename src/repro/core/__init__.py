"""OptCC core: the paper's contribution as a reusable library.

Public API:
  BandwidthProfile, Flow, Op, Schedule       - flow model (core.model)
  validate_schedule_meta                     - Schedule.meta key contract
  simulate, simulate_many, SimResult         - bandwidth simulator
  execute, verify_allreduce                  - data-level verification
  registry                                   - named schedule generators
                                               (ring/optcc/hierarchical/
                                               dbtree/torus2d)
  make_plan, Plan, topology_of               - online planner;
                                               make_plan(algo="auto"|name)
  lower_bounds                               - Theorems 1,2,3,6,13 + times,
                                               plus per-topology bounds

Deprecated (still importable, with a DeprecationWarning): the direct
generator entry points `ring_allreduce_schedule`, `optcc_schedule`,
`optcc_single_schedule`, `optcc_multi_schedule`, `optcc_multi_gpu_schedule`.
Use `make_plan(profile, n, k, algo=...)` or `registry.get(name).generate`;
the concrete functions remain public at their defining modules
(`repro.core.ring`, `repro.core.schedule`) for tests and internals.
"""
import warnings as _warnings

from repro.core import lower_bounds, registry
from repro.core.baselines import (iccl_time_asymptotic, iccl_time_simulated,
                                  nccl_no_failure_time, r2ccl_time)
from repro.core.executor import execute, verify_allreduce
from repro.core.model import (BandwidthProfile, Flow, Op, Schedule,
                              validate_schedule_meta)
from repro.core.planner import Plan, make_plan, topology_of
from repro.core.simulator import SimResult, simulate, simulate_many

__all__ = [
    "BandwidthProfile", "Flow", "Op", "Schedule", "SimResult", "simulate",
    "simulate_many", "validate_schedule_meta",
    "execute", "verify_allreduce", "registry", "ring_allreduce_schedule",
    "optcc_schedule", "optcc_single_schedule", "optcc_multi_schedule",
    "optcc_multi_gpu_schedule", "make_plan", "Plan", "topology_of",
    "lower_bounds",
    "nccl_no_failure_time", "iccl_time_asymptotic", "iccl_time_simulated",
    "r2ccl_time",
]

_DEPRECATED = {
    "ring_allreduce_schedule": ("repro.core.ring", "ring_allreduce_schedule"),
    "optcc_schedule": ("repro.core.schedule", "optcc_schedule"),
    "optcc_single_schedule": ("repro.core.schedule", "optcc_single_schedule"),
    "optcc_multi_schedule": ("repro.core.schedule", "optcc_multi_schedule"),
    "optcc_multi_gpu_schedule": ("repro.core.schedule",
                                 "optcc_multi_gpu_schedule"),
}


def __getattr__(name):
    """Lazy deprecation shims for the pre-registry generator entry points."""
    try:
        module, attr = _DEPRECATED[name]
    except KeyError:
        raise AttributeError(
            f"module {__name__!r} has no attribute {name!r}") from None
    _warnings.warn(
        f"importing {name} from repro.core is deprecated; use "
        f"repro.core.make_plan(algo=...) / repro.core.registry.get(...), "
        f"or import it from {module}",
        DeprecationWarning, stacklevel=2)
    import importlib
    return getattr(importlib.import_module(module), attr)
