"""Online re-planning: bandwidth profile -> collective plan.

When the runtime's failure detector reports a degradation event (NIC loss,
rerouted ICI link, DCN egress fault), the planner picks the schedule for the
new bandwidth profile. Generation is closed-form (O(p k), Section 4.3) - no
solver - so this happens inline at failure-detection time; the paper reports
< 1 ms for p=1024 and benchmarks/schedule_gen_speed.py measures ours.

The plan also carries the theory: the lower bound for the profile and the
predicted completion time, so the runtime can (a) sanity-check the simulator
against the theory and (b) expose expected-overhead metrics to operators.
"""
from __future__ import annotations

import dataclasses
import time

from repro.core import lower_bounds as lb
from repro.core.model import BandwidthProfile, Schedule
from repro.core.schedule import optcc_schedule


@dataclasses.dataclass
class Plan:
    profile: BandwidthProfile
    schedule: Schedule | None    # None when materialize=False
    algo: str                    # "ring" (healthy) or "optcc-*"
    lower_bound: float           # element-time units
    predicted_time: float        # closed-form achieved time
    t0: float                    # fault-free optimum
    gen_seconds: float           # wall time to construct the plan
    descriptor: dict = dataclasses.field(default_factory=dict)

    @property
    def predicted_overhead(self) -> float:
        """Predicted slowdown vs the fault-free optimum (1.0 = none)."""
        return self.predicted_time / self.t0 if self.t0 else float("inf")


def plan_descriptor(profile: BandwidthProfile, n: int, k: int) -> dict:
    """O(p k) closed-form schedule descriptor (Section 4.3's complexity
    claim): per-(segment, section) slot offsets; the per-hop flow graph is
    implied by the closed-form chain rules and only materialized when the
    runtime (or simulator) needs individual flows."""
    p = profile.p
    stragglers = profile.stragglers
    ell = max(profile.slowdown)
    ph = p - max(len(stragglers), 1) if stragglers else p
    s_i = n / max(k * ph, 1)
    w = max(ell, 2.0)
    body = w * ph * s_i
    slots = {}
    for m in range(k):
        for j in range(ph):
            nu = (j + m) % ph
            slots[(m, j)] = (
                nu,                                   # owner index
                m * body + (2 * nu + ph) * s_i,       # S1 chain start
                (m + 2) * body + 2 * nu * s_i - 2,    # S2 slot
                (m + 3) * body + 2 * nu * s_i - 4,    # S3 slot
                (m + 3) * body + (2 * nu + 2 * ph - 3) * s_i,  # S4 start
            )
    return {"algo": "optcc" if stragglers else "ring", "k": k,
            "body": body, "slots": slots}


def make_plan(profile: BandwidthProfile, n: int, k: int = 16,
              fill_bubbles: bool = True, materialize: bool = True) -> Plan:
    t_start = time.perf_counter()
    descriptor = plan_descriptor(profile, n, k)
    schedule = optcc_schedule(profile, n, k, fill_bubbles) if materialize \
        else None
    gen_s = time.perf_counter() - t_start
    g = profile.gpus_per_server
    ells = [l for l in profile.slowdown if l > 1.0]
    # De-duplicate per-server slowdowns in the multi-GPU case.
    if g > 1 and ells:
        ells = [max(ells)]
    if schedule is not None:
        algo = schedule.meta["algo"]
    elif not profile.stragglers:
        algo = "ring"
    elif g > 1:
        algo = "optcc-multigpu"
    else:
        algo = "optcc-single" if len(ells) == 1 else "optcc-multi"
    return Plan(
        profile=profile,
        schedule=schedule,
        algo=algo,
        lower_bound=lb.lower_bound(profile.p, n, ells, g),
        predicted_time=lb.optcc_time(profile.p, n, ells, k, g),
        t0=lb.t0_fault_free(profile.p, n, g),
        gen_seconds=gen_s,
        descriptor=descriptor,
    )
