"""Online re-planning: bandwidth profile -> collective plan.

When the runtime's failure detector reports a degradation event (NIC loss,
rerouted ICI link, DCN egress fault), the planner picks the schedule for the
new bandwidth profile. Generation is closed-form (O(p k), Section 4.3) - no
solver - so this happens inline at failure-detection time; the paper reports
< 1 ms for p=1024 and benchmarks/schedule_gen_speed.py measures ours.

The plan also carries the theory: the lower bound for the profile and the
predicted completion time, so the runtime can (a) sanity-check the simulator
against the theory and (b) expose expected-overhead metrics to operators.
"""
from __future__ import annotations

import dataclasses
import time
from collections.abc import Mapping

import numpy as np

from repro.core import lower_bounds as lb
from repro.core.model import BandwidthProfile, FaultTimeline, Schedule
from repro.core.schedule import optcc_schedule


@dataclasses.dataclass
class Plan:
    profile: BandwidthProfile
    schedule: Schedule | None    # None when materialize=False
    algo: str                    # "ring" (healthy) or "optcc-*"
    lower_bound: float           # element-time units
    predicted_time: float        # closed-form achieved time
    t0: float                    # fault-free optimum
    gen_seconds: float           # wall time to construct the plan
    descriptor: dict = dataclasses.field(default_factory=dict)

    @property
    def predicted_overhead(self) -> float:
        """Predicted slowdown vs the fault-free optimum (1.0 = none)."""
        return self.predicted_time / self.t0 if self.t0 else float("inf")


class _SlotTable(Mapping):
    """Read-only (segment, section) -> slot-tuple view over the batched
    descriptor array. Behaves like the dict it replaced (len, [], in,
    .keys()/.items()), but construction is O(1) Python objects - tuples are
    materialized only for the entries actually read, which is what keeps the
    p=1024 descriptor under the 1 ms re-planning budget."""

    __slots__ = ("_cols",)

    def __init__(self, cols: np.ndarray):
        self._cols = cols                     # (k, ph, 5) float64

    def __getitem__(self, key):
        m, j = key
        k, ph, _ = self._cols.shape
        if not (0 <= m < k and 0 <= j < ph):
            raise KeyError(key)
        nu, t1, t2, t3, t4 = self._cols[m, j].tolist()
        return (int(nu), t1, t2, t3, t4)

    def __len__(self):
        return self._cols.shape[0] * self._cols.shape[1]

    def __iter__(self):
        k, ph, _ = self._cols.shape
        return ((m, j) for m in range(k) for j in range(ph))


def plan_descriptor(profile: BandwidthProfile, n: int, k: int) -> dict:
    """O(p k) closed-form schedule descriptor (Section 4.3's complexity
    claim): per-(segment, section) slot offsets; the per-hop flow graph is
    implied by the closed-form chain rules and only materialized when the
    runtime (or simulator) needs individual flows.

    All five slot columns are in element-time units and scale linearly with
    n (every term carries a factor of the slot width s_i; an earlier version
    subtracted raw constants from the S2/S3 slots, which broke unit
    consistency and went negative for small n). Computed as one batched
    numpy program over the (k, p-1) grid - this is the <1 ms re-planning
    path gated by ci/sweep_thresholds.json (schedgen_latency_ms_max)."""
    p = profile.p
    stragglers = profile.stragglers
    ell = max(profile.slowdown)
    ph = p - max(len(stragglers), 1) if stragglers else p
    s_i = n / max(k * ph, 1)
    w = max(ell, 2.0)
    body = w * ph * s_i
    m = np.arange(k, dtype=np.float64)[:, None]          # segment
    j = np.arange(ph, dtype=np.float64)[None, :]         # section
    nu = (j + m) % ph                                    # owner index
    cols = np.empty((k, ph, 5))
    cols[:, :, 0] = nu
    cols[:, :, 1] = m * body + (2.0 * nu + ph) * s_i         # S1 chain start
    cols[:, :, 2] = (m + 2) * body + (2.0 * nu - 2.0) * s_i  # S2 slot
    cols[:, :, 3] = (m + 3) * body + (2.0 * nu - 4.0) * s_i  # S3 slot
    cols[:, :, 4] = (m + 3) * body + (2.0 * nu + 2.0 * ph - 3.0) * s_i  # S4
    return {"algo": "optcc" if stragglers else "ring", "k": k,
            "body": body, "slots": _SlotTable(cols),
            # Column semantics for columns 1..4 of the slot table, matching
            # the stage vocabulary flows are tagged with (model.STAGE_NAMES)
            # so telemetry breakdowns line up with planned slot starts.
            "stage_slots": ("S1", "S2", "S3", "S4")}


def make_plan(profile: BandwidthProfile, n: int, k: int = 16,
              fill_bubbles: bool = True,
              materialize: bool | str = True) -> Plan:
    """materialize=True -> Flow-object schedule (executor-ready);
    materialize="arrays" -> columnar schedule (simulator hot path; same
    flow graph, no Flow objects); materialize=False -> descriptor only.

    The planner picks the *predicted-faster* of OptCC and the FIFO ring.
    The FIFO ring on a degraded profile costs exactly l_max 2(p-1)n/p (the
    slowest link paces a contention-free ring), so when OptCC's pipeline
    fill would cost more - small p, shallow k, l close to 1 - staying on
    the ring is the right call, and the calibrated optcc_time (within 10%
    of the simulator, tests/test_schedule_time.py) makes this comparison
    trustworthy at planning time."""
    t_start = time.perf_counter()
    g = profile.gpus_per_server
    ells = [l for l in profile.slowdown if l > 1.0]
    # De-duplicate per-server slowdowns in the multi-GPU case.
    if g > 1 and ells:
        ells = [max(ells)]
    optcc_pred = lb.optcc_time(profile.p, n, ells, k, g)
    ring_pred = max(profile.slowdown) * lb.t0_fault_free(profile.p, n, 1)
    use_ring = ring_pred <= optcc_pred      # healthy profiles tie -> ring
    descriptor = plan_descriptor(profile, n, k)
    if use_ring:
        descriptor["algo"] = "ring"
    if materialize == "arrays":
        from repro.core.schedule_vec import optcc_schedule_arrays, ring_arrays
        schedule = ring_arrays(profile, n) if use_ring else \
            optcc_schedule_arrays(profile, n, k, fill_bubbles)
    elif materialize:
        if use_ring:
            from repro.core.ring import ring_allreduce_schedule
            schedule = ring_allreduce_schedule(profile, n)
        else:
            schedule = optcc_schedule(profile, n, k, fill_bubbles)
    else:
        schedule = None
    gen_s = time.perf_counter() - t_start
    if schedule is not None:
        algo = schedule.meta["algo"]
    elif use_ring:
        algo = "ring"
    elif g > 1:
        algo = "optcc-multigpu"
    else:
        algo = "optcc-single" if len(ells) == 1 else "optcc-multi"
    return Plan(
        profile=profile,
        schedule=schedule,
        algo=algo,
        lower_bound=lb.lower_bound(profile.p, n, ells, g),
        predicted_time=ring_pred if use_ring else optcc_pred,
        t0=lb.t0_fault_free(profile.p, n, g),
        gen_seconds=gen_s,
        descriptor=descriptor,
    )


@dataclasses.dataclass
class ReplayResult:
    """Outcome of `replay`: one collective run under a failure timeline,
    with and without mid-flight re-planning.

    ``t_noreplan`` is the original plan ridden through every rate change;
    ``t_chain`` is the replanned chain's completion time (splice at each
    breakpoint: drain the in-flight flows, re-plan the remaining elements
    for the rates then in force, repeat on the residual timeline). The
    controller modeled here sees both and adopts the better one, so the
    reported ``t_replan`` is their min - re-planning can only help.
    """

    profile: BandwidthProfile      # base profile (timeline t=0 events folded)
    timeline: FaultTimeline
    n: float
    t_noreplan: float              # original plan under the full timeline
    t_chain: float                 # replanned chain completion time
    replans: int                   # splices performed along the chain
    lower_bound: float             # timeline_lower_bound (best-ever rates)
    t0: float                      # fault-free optimum for (p, n, g)
    plan0: Plan                    # the initial plan (before any splice)
    # SimResult of the no-replan run (plan0 under the full timeline) - kept
    # so callers can attribute t_noreplan per stage (repro.obs) without
    # re-simulating.
    noreplan_result: object = None

    @property
    def t_replan(self) -> float:
        """Makespan with the re-planning controller on (adopts the better)."""
        return min(self.t_chain, self.t_noreplan)

    @property
    def adopted_replan(self) -> bool:
        return self.t_chain < self.t_noreplan


def replay(profile: BandwidthProfile, n: int, timeline: FaultTimeline,
           k: int = 16, fill_bubbles: bool = True,
           max_replans: int = 8) -> ReplayResult:
    """Run one AllReduce under a failure timeline, re-planning mid-flight.

    The no-replan baseline simulates the initial plan (built for the
    profile in force at t=0, timeline t<=0 events folded in) under the full
    timeline. The replan chain models the runtime's failure detector firing
    at each effective breakpoint b:

      * flows already on the wire at b drain to completion (they hold their
        ports and never wait again, so their finishes in the no-replan
        simulation are already exact);
      * flows not yet started are cancelled; the work they carried -
        ``(1 - progress)`` of the current vector, measured in NIC wire
        elements - is re-planned from scratch via `make_plan` against the
        profile in force at the drain time, and the residual timeline
        (later events, shifted to the new plan's clock) recurses.

    The chain is an idealized controller (zero detection and generation
    latency - `make_plan` is < 1 ms against multi-second collectives, so
    the approximation is tight) and the adopted result is
    ``min(chain, no-replan)``: see `ReplayResult`.

    The strict wins come from slotted OptCC's release times: they are
    computed for the *degraded* rates, so after a recovery the no-replan
    schedule still paces itself as if the straggler were there, while the
    replanned remainder runs at full speed.
    """
    from repro.core.simulator import simulate

    if max_replans < 0:
        raise ValueError("max_replans must be >= 0")
    base = timeline.profile_at(profile, 0.0)
    tl0 = timeline.after(0.0)
    plan0 = make_plan(base, n, k, fill_bubbles)
    res0 = simulate(plan0.schedule, timeline=tl0)
    t_noreplan = res0.makespan

    # Replanned chain: walk breakpoints, splicing a fresh plan at each.
    t_off = 0.0
    n_cur = float(n)
    prof_cur = base
    tl_cur = tl0
    plan_cur, res_cur = plan0, res0
    replans = 0
    t_chain = t_noreplan
    while True:
        breaks, _ = tl_cur.segments(prof_cur)
        b = next((bt for bt in breaks if bt < res_cur.makespan), None)
        if b is None or replans >= max_replans:
            t_chain = t_off + res_cur.makespan
            break
        starts = res_cur.start
        finishes = res_cur.finish
        wire = [f for f in plan_cur.schedule.nic_flows if f.size > 0]
        started = [f for f in wire if starts[f.fid] < b]
        total_work = sum(f.size for f in wire)
        done_work = sum(f.size for f in started)
        progress = done_work / total_work if total_work else 1.0
        n_rem = int(round(n_cur * (1.0 - progress)))
        if n_rem <= 0:
            # Everything is already on the wire; nothing left to re-plan.
            t_chain = t_off + res_cur.makespan
            break
        # Drain: in-flight flows keep their ports until done, so their
        # finishes in res_cur are exact regardless of the cancellations.
        t_d = max([b] + [finishes[f.fid] for f in started])
        prof_cur = tl_cur.profile_at(prof_cur, t_d)
        tl_cur = tl_cur.after(t_d)
        t_off += t_d
        n_cur = float(n_rem)
        replans += 1
        plan_cur = make_plan(prof_cur, n_rem, k, fill_bubbles)
        res_cur = simulate(plan_cur.schedule, timeline=tl_cur)

    return ReplayResult(
        profile=base,
        timeline=tl0,
        n=float(n),
        t_noreplan=t_noreplan,
        t_chain=t_chain,
        replans=replans,
        lower_bound=lb.timeline_lower_bound(base, tl0, n),
        t0=lb.t0_fault_free(base.p, n, base.gpus_per_server),
        plan0=plan0,
        noreplan_result=res0,
    )
