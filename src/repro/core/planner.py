"""Online re-planning: bandwidth profile -> collective plan.

When the runtime's failure detector reports a degradation event (NIC loss,
rerouted ICI link, DCN egress fault), the planner picks the schedule for the
new bandwidth profile. Generation is closed-form (O(p k), Section 4.3) - no
solver - so this happens inline at failure-detection time; the paper reports
< 1 ms for p=1024 and benchmarks/schedule_gen_speed.py measures ours.

The plan also carries the theory: the lower bound for the profile and the
predicted completion time, so the runtime can (a) sanity-check the simulator
against the theory and (b) expose expected-overhead metrics to operators.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from collections.abc import Mapping

import numpy as np

from repro.core import lower_bounds as lb
from repro.core import registry
from repro.core.model import BandwidthProfile, FaultTimeline, Schedule
from repro.core.schedule import optcc_schedule


def topology_of(algo: str) -> str:
    """Normalize a plan/schedule `algo` to its registry topology name: the
    optcc dispatcher's per-regime variants ("optcc-single", "optcc-multi",
    "optcc-multigpu") all collapse to "optcc"; everything else (ring,
    hierarchical, dbtree, torus2d) is its own topology."""
    if algo.startswith("optcc"):
        return "optcc"
    return algo


@dataclasses.dataclass
class Plan:
    profile: BandwidthProfile
    schedule: Schedule | None    # None when materialize=False
    algo: str                    # "ring", "optcc-*", or a registry name
    lower_bound: float           # element-time units
    predicted_time: float        # closed-form achieved time
    t0: float                    # fault-free optimum
    gen_seconds: float           # wall time to construct the plan
    descriptor: dict = dataclasses.field(default_factory=dict)
    topology: str = ""           # registry name (topology_of(algo))

    def __post_init__(self):
        if not self.topology:
            self.topology = topology_of(self.algo)

    @property
    def predicted_overhead(self) -> float:
        """Predicted slowdown vs the fault-free optimum (1.0 = none)."""
        return self.predicted_time / self.t0 if self.t0 else float("inf")


class _SlotTable(Mapping):
    """Read-only (segment, section) -> slot-tuple view over the batched
    descriptor array. Behaves like the dict it replaced (len, [], in,
    .keys()/.items()), but construction is O(1) Python objects - tuples are
    materialized only for the entries actually read, which is what keeps the
    p=1024 descriptor under the 1 ms re-planning budget."""

    __slots__ = ("_cols",)

    def __init__(self, cols: np.ndarray):
        self._cols = cols                     # (k, ph, 5) float64

    def __getitem__(self, key):
        m, j = key
        k, ph, _ = self._cols.shape
        if not (0 <= m < k and 0 <= j < ph):
            raise KeyError(key)
        nu, t1, t2, t3, t4 = self._cols[m, j].tolist()
        return (int(nu), t1, t2, t3, t4)

    def __len__(self):
        return self._cols.shape[0] * self._cols.shape[1]

    def __iter__(self):
        k, ph, _ = self._cols.shape
        return ((m, j) for m in range(k) for j in range(ph))


def plan_descriptor(profile: BandwidthProfile, n: int, k: int) -> dict:
    """O(p k) closed-form schedule descriptor (Section 4.3's complexity
    claim): per-(segment, section) slot offsets; the per-hop flow graph is
    implied by the closed-form chain rules and only materialized when the
    runtime (or simulator) needs individual flows.

    All five slot columns are in element-time units and scale linearly with
    n (every term carries a factor of the slot width s_i; an earlier version
    subtracted raw constants from the S2/S3 slots, which broke unit
    consistency and went negative for small n). Computed as one batched
    numpy program over the (k, p-1) grid - this is the <1 ms re-planning
    path gated by ci/sweep_thresholds.json (schedgen_latency_ms_max)."""
    p = profile.p
    stragglers = profile.stragglers
    ell = max(profile.slowdown)
    ph = p - max(len(stragglers), 1) if stragglers else p
    s_i = n / max(k * ph, 1)
    w = max(ell, 2.0)
    body = w * ph * s_i
    m = np.arange(k, dtype=np.float64)[:, None]          # segment
    j = np.arange(ph, dtype=np.float64)[None, :]         # section
    nu = (j + m) % ph                                    # owner index
    cols = np.empty((k, ph, 5))
    cols[:, :, 0] = nu
    cols[:, :, 1] = m * body + (2.0 * nu + ph) * s_i         # S1 chain start
    cols[:, :, 2] = (m + 2) * body + (2.0 * nu - 2.0) * s_i  # S2 slot
    cols[:, :, 3] = (m + 3) * body + (2.0 * nu - 4.0) * s_i  # S3 slot
    cols[:, :, 4] = (m + 3) * body + (2.0 * nu + 2.0 * ph - 3.0) * s_i  # S4
    return {"algo": "optcc" if stragglers else "ring", "k": k,
            "body": body, "slots": _SlotTable(cols),
            # Column semantics for columns 1..4 of the slot table, matching
            # the stage vocabulary flows are tagged with (model.STAGE_NAMES)
            # so telemetry breakdowns line up with planned slot starts.
            "stage_slots": ("S1", "S2", "S3", "S4")}


def make_plan(profile: BandwidthProfile, n: int, k: int = 16,
              fill_bubbles: bool = True,
              materialize: bool | str = True,
              algo: str = "auto",
              force_ring: bool | None = None) -> Plan:
    """materialize=True -> Flow-object schedule (executor-ready);
    materialize="arrays" -> columnar schedule (simulator hot path; same
    flow graph, no Flow objects); materialize=False -> descriptor only.

    ``algo`` selects from the schedule registry (`core.registry`):

    * ``"auto"`` (default) compares the auto-eligible registered time
      models and picks the predicted-fastest. Today that is OptCC vs the
      FIFO ring, exactly the historical planner choice: the ring on a
      degraded profile costs exactly l_max 2(p-1)n/p (the slowest link
      paces a contention-free ring), so when OptCC's pipeline fill would
      cost more - small p, shallow k, l close to 1 - staying on the ring
      is the right call, and the calibrated optcc_time (within 10% of the
      simulator, tests/test_schedule_time.py) makes the comparison
      trustworthy at planning time. Ties go to the ring.
    * ``"ring"`` plans the FIFO ring unconditionally - the mis-plan
      fallback `replay` takes when a fault detector's estimate is not
      credible enough to pick a straggler set from
      (`repro.detect.estimate_usable`). The ring is valid under any
      profile, including ones OptCC's closed form would degenerate on
      (e.g. an estimate claiming p-1 stragglers).
    * ``"optcc"`` plans the paper's schedule family unconditionally.
    * any other registered name (``"hierarchical"``, ``"dbtree"``,
      ``"torus2d"``, ...) plans that topology; its `lower_bound` /
      `predicted_time` come from the registry entry's own bound and time
      model. Raises ValueError for unknown names or unsupported profiles.

    ``force_ring`` is the deprecated boolean this keyword replaced;
    passing it (either value) emits a DeprecationWarning."""
    if force_ring is not None:
        warnings.warn(
            "make_plan(force_ring=...) is deprecated; use "
            "make_plan(algo='ring') instead of force_ring=True "
            "(and algo='auto' instead of force_ring=False)",
            DeprecationWarning, stacklevel=2)
        if force_ring:
            algo = "ring"
    if algo in ("auto", "ring", "optcc"):
        return _make_plan_classic(profile, n, k, fill_bubbles, materialize,
                                  algo)
    t_start = time.perf_counter()
    entry = registry.get(algo)
    if not entry.supports(profile):
        raise ValueError(
            f"algo {algo!r} does not support this profile "
            f"(p={profile.p}, gpus_per_server={profile.gpus_per_server}); "
            f"supported here: {', '.join(registry.supported(profile))}")
    if materialize == "arrays":
        gen = entry.generate_arrays or entry.generate
        schedule = gen(profile, n, k, fill_bubbles)
    elif materialize:
        schedule = entry.generate(profile, n, k, fill_bubbles)
    else:
        schedule = None
    gen_s = time.perf_counter() - t_start
    plan_algo = schedule.meta["algo"] if schedule is not None else algo
    return Plan(
        profile=profile,
        schedule=schedule,
        algo=plan_algo,
        lower_bound=entry.lower_bound(profile, n),
        predicted_time=entry.time_model(profile, n, k),
        t0=lb.t0_fault_free(profile.p, n, profile.gpus_per_server),
        gen_seconds=gen_s,
        descriptor={"algo": algo, "k": k},
        topology=topology_of(plan_algo),
    )


def _make_plan_classic(profile: BandwidthProfile, n: int, k: int,
                       fill_bubbles: bool, materialize: bool | str,
                       algo: str) -> Plan:
    """The OptCC-vs-ring planner (algo in auto/ring/optcc). Kept as one
    inline path - not a loop over registry entries - so `algo="auto"` stays
    bit-identical to the PR-6 planner; the registry's ring/optcc time
    models mirror these expressions and tests/test_registry.py pins the
    equality."""
    t_start = time.perf_counter()
    g = profile.gpus_per_server
    ells = [l for l in profile.slowdown if l > 1.0]
    # De-duplicate per-server slowdowns in the multi-GPU case.
    if g > 1 and ells:
        ells = [max(ells)]
    ring_pred = max(profile.slowdown) * lb.t0_fault_free(profile.p, n, 1)
    if algo == "ring":
        optcc_pred = ring_pred
        use_ring = True
        descriptor = {"algo": "ring", "k": k}
    else:
        optcc_pred = lb.optcc_time(profile.p, n, ells, k, g)
        use_ring = (algo == "auto"
                    and ring_pred <= optcc_pred)  # healthy ties -> ring
        descriptor = plan_descriptor(profile, n, k)
    if use_ring:
        descriptor["algo"] = "ring"
    if materialize == "arrays":
        from repro.core.schedule_vec import optcc_schedule_arrays, ring_arrays
        schedule = ring_arrays(profile, n) if use_ring else \
            optcc_schedule_arrays(profile, n, k, fill_bubbles)
    elif materialize:
        if use_ring:
            from repro.core.ring import ring_allreduce_schedule
            schedule = ring_allreduce_schedule(profile, n)
        else:
            schedule = optcc_schedule(profile, n, k, fill_bubbles)
    else:
        schedule = None
    gen_s = time.perf_counter() - t_start
    if schedule is not None:
        plan_algo = schedule.meta["algo"]
    elif use_ring:
        plan_algo = "ring"
    elif g > 1:
        plan_algo = "optcc-multigpu"
    else:
        plan_algo = "optcc-single" if len(ells) == 1 else "optcc-multi"
    return Plan(
        profile=profile,
        schedule=schedule,
        algo=plan_algo,
        lower_bound=lb.lower_bound(profile.p, n, ells, g),
        predicted_time=ring_pred if use_ring else optcc_pred,
        t0=lb.t0_fault_free(profile.p, n, g),
        gen_seconds=gen_s,
        descriptor=descriptor,
        topology=topology_of(plan_algo),
    )


@dataclasses.dataclass
class ReplayResult:
    """Outcome of `replay`: one collective run under a failure timeline,
    with and without mid-flight re-planning.

    ``t_noreplan`` is the original plan ridden through every rate change;
    ``t_chain`` is the replanned chain's completion time (splice at each
    breakpoint: drain the in-flight flows, re-plan the remaining elements
    for the rates then in force, repeat on the residual timeline). The
    controller modeled here sees both and adopts the better one, so the
    reported ``t_replan`` is their min - re-planning can only help.
    """

    profile: BandwidthProfile      # base profile (timeline t=0 events folded)
    timeline: FaultTimeline
    n: float
    t_noreplan: float              # original plan under the full timeline
    t_chain: float                 # replanned chain completion time
    replans: int                   # splices performed along the chain
    lower_bound: float             # timeline_lower_bound (best-ever rates)
    t0: float                      # fault-free optimum for (p, n, g)
    plan0: Plan                    # the initial plan (before any splice)
    # SimResult of the no-replan run (plan0 under the full timeline) - kept
    # so callers can attribute t_noreplan per stage (repro.obs) without
    # re-simulating.
    noreplan_result: object = None
    # Imperfect-detection fields (repro.detect). policy="oracle" marks the
    # PR-8 zero-delay perfect-knowledge controller (detector=None).
    policy: str = "oracle"
    detector: object = None        # detect.DetectorConfig | None
    detection: object = None       # detect.DetectionResult | None
    false_replans: int = 0         # splices with no true rate change behind
    suppressed: int = 0            # estimated changes the policy swallowed

    @property
    def t_replan(self) -> float:
        """Makespan with the re-planning controller on (adopts the better)."""
        return min(self.t_chain, self.t_noreplan)

    @property
    def adopted_replan(self) -> bool:
        return self.t_chain < self.t_noreplan

    @property
    def detect_lag_mean(self) -> float | None:
        return None if self.detection is None else self.detection.lag_mean

    @property
    def detect_lag_max(self) -> float | None:
        return None if self.detection is None else self.detection.lag_max


def replay(profile: BandwidthProfile, n: int, timeline: FaultTimeline,
           k: int = 16, fill_bubbles: bool = True,
           max_replans: int = 8,
           detector: object = None,
           controller: object = None) -> ReplayResult:
    """Run one AllReduce under a failure timeline, re-planning mid-flight.

    The no-replan baseline simulates the initial plan (built for the
    profile in force at t=0, timeline t<=0 events folded in) under the full
    timeline. The replan chain models the runtime's failure detector firing
    at each effective breakpoint b:

      * flows already on the wire at b drain to completion (they hold their
        ports and never wait again, so their finishes in the no-replan
        simulation are already exact);
      * flows not yet started are cancelled; the work they carried -
        ``(1 - progress)`` of the current vector, measured in NIC wire
        elements - is re-planned from scratch via `make_plan` against the
        profile in force at the drain time, and the residual timeline
        (later events, shifted to the new plan's clock) recurses.

    With ``detector=None`` (the default) the controller is the PR-8
    *oracle*: zero detection latency, perfect knowledge of the new rates.
    The adopted result is ``min(chain, no-replan)``: see `ReplayResult`.

    With a `repro.detect.DetectorConfig`, the controller reacts to the
    *estimated* timeline instead: triggers are the breakpoints of the
    detector's estimate (lagged, noisy, possibly spurious), filtered by the
    `repro.detect.ControllerConfig` policy (``immediate`` / ``debounce`` /
    ``backoff``), and every spliced plan is built from the estimated
    profile at the drain time. Execution stays truth-grounded - mis-plan
    tolerance: the (possibly wrong) schedule is simulated under the *true*
    rates by folding per-rank truth corrections into the simulation
    timeline at t=0, so a plan built for the wrong straggler or wrong ell
    still yields a valid, correctly-timed run; when the estimate is not
    credible enough to pick a straggler set from
    (`repro.detect.estimate_usable`) the splice falls back to the degraded
    FIFO ring. A perfect detector with the ``immediate`` policy reproduces
    the oracle bit-for-bit (tests/test_detect.py pins this on every
    checked-in ci/traces file).

    The strict wins come from slotted OptCC's release times: they are
    computed for the *degraded* rates, so after a recovery the no-replan
    schedule still paces itself as if the straggler were there, while the
    replanned remainder runs at full speed.
    """
    from repro.core.model import FaultEvent
    from repro.core.simulator import simulate

    if max_replans < 0:
        raise ValueError("max_replans must be >= 0")
    base = timeline.profile_at(profile, 0.0)
    tl0 = timeline.after(0.0)
    plan0 = make_plan(base, n, k, fill_bubbles)
    res0 = simulate(plan0.schedule, timeline=tl0)
    t_noreplan = res0.makespan

    detection = None
    suppressed = 0
    ctrl = None
    est_tl0 = tl0
    if detector is not None:
        from repro.detect import (ControllerConfig, apply_policy,
                                  estimate_timeline)
        ctrl = controller if controller is not None else ControllerConfig()
        # The horizon must cover everything the chain could react to: the
        # no-replan makespan, the last true event, plus the detector's own
        # lag sources (sensing latency, debounce window, a couple probes).
        last_ev = max((e.t for e in tl0.events), default=0.0)
        dt = detector.probe_interval
        window = (ctrl.debounce_probes - 1) * dt \
            if ctrl.policy == "debounce" else 0.0
        horizon = max(t_noreplan, last_ev) + detector.latency + window \
            + 2.0 * dt
        detection = estimate_timeline(base, tl0, horizon, detector)
        est_tl0, suppressed = apply_policy(detection, base, ctrl)
    elif controller is not None:
        raise ValueError("a controller policy needs a detector "
                         "(detector=None runs the zero-delay oracle)")

    # Replanned chain: walk trigger breakpoints, splicing a fresh plan at
    # each. Triggers and plan profiles come from the estimated view
    # (== the truth in oracle mode); drains and simulations from the truth.
    t_off = 0.0
    n_cur = float(n)
    prof_cur = base
    tl_cur = tl0
    est_prof_cur = base
    est_tl_cur = est_tl0
    plan_cur, res_cur = plan0, res0
    replans = 0
    false_replans = 0
    not_before = 0.0               # backoff floor, absolute chain time
    t_chain = t_noreplan
    while True:
        if detector is None:
            breaks, _ = tl_cur.segments(prof_cur)
        else:
            breaks, _ = est_tl_cur.segments(est_prof_cur)
        b = next((bt for bt in breaks if bt < res_cur.makespan), None)
        if b is not None and ctrl is not None and ctrl.policy == "backoff" \
                and t_off + b < not_before:
            # Defer (and thereby coalesce) triggers inside the spacing
            # floor; a floor beyond the current run's makespan ends the
            # chain - the remaining estimated changes go unanswered.
            b = not_before - t_off
            if b >= res_cur.makespan:
                b = None
        if b is None or replans >= max_replans:
            t_chain = t_off + res_cur.makespan
            break
        starts = res_cur.start
        finishes = res_cur.finish
        wire = [f for f in plan_cur.schedule.nic_flows if f.size > 0]
        started = [f for f in wire if starts[f.fid] < b]
        total_work = sum(f.size for f in wire)
        done_work = sum(f.size for f in started)
        progress = done_work / total_work if total_work else 1.0
        n_rem = int(round(n_cur * (1.0 - progress)))
        if n_rem <= 0:
            # Everything is already on the wire; nothing left to re-plan.
            t_chain = t_off + res_cur.makespan
            break
        # Drain: in-flight flows keep their ports until done, so their
        # finishes in res_cur are exact regardless of the cancellations.
        t_d = max([b] + [finishes[f.fid] for f in started])
        prev_true = prof_cur
        prof_cur = tl_cur.profile_at(prof_cur, t_d)
        tl_cur = tl_cur.after(t_d)
        if detector is None:
            est_prof_cur, est_tl_cur = prof_cur, tl_cur
        else:
            est_prof_cur = est_tl_cur.profile_at(est_prof_cur, t_d)
            est_tl_cur = est_tl_cur.after(t_d)
        t_off += t_d
        n_cur = float(n_rem)
        replans += 1
        if detector is not None \
                and prof_cur.slowdown == prev_true.slowdown:
            # The trigger had no true rate change behind it (an FP blip, or
            # a flap that cleared before the drain finished): pure thrash.
            false_replans += 1
        if detector is None:
            plan_cur = make_plan(prof_cur, n_rem, k, fill_bubbles)
            sim_tl = tl_cur
        else:
            from repro.detect import estimate_usable
            plan_cur = make_plan(
                est_prof_cur, n_rem, k, fill_bubbles,
                algo="auto" if estimate_usable(est_prof_cur) else "ring")
            # Mis-plan execution: the schedule was built for the estimated
            # rates, but the wire runs at the true ones. Events SET
            # absolute per-rank values, so t=0 corrections re-ground the
            # simulation in the truth regardless of the plan's beliefs.
            corr = tuple(
                FaultEvent(0.0, r, tv)
                for r, (tv, ev) in enumerate(zip(prof_cur.slowdown,
                                                 est_prof_cur.slowdown))
                if tv != ev)
            sim_tl = FaultTimeline(corr + tl_cur.events) if corr else tl_cur
        res_cur = simulate(plan_cur.schedule, timeline=sim_tl)
        if ctrl is not None and ctrl.policy == "backoff":
            not_before = t_off + ctrl.backoff_spacing(
                detector.probe_interval, replans)

    return ReplayResult(
        profile=base,
        timeline=tl0,
        n=float(n),
        t_noreplan=t_noreplan,
        t_chain=t_chain,
        replans=replans,
        lower_bound=lb.timeline_lower_bound(base, tl0, n),
        t0=lb.t0_fault_free(base.p, n, base.gpus_per_server),
        plan0=plan0,
        noreplan_result=res0,
        policy="oracle" if detector is None else ctrl.policy,
        detector=detector,
        detection=detection,
        false_replans=false_replans,
        suppressed=suppressed,
    )
