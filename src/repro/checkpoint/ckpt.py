"""Checkpoint/restart: atomic, resumable, reshard-tolerant.

Layout: <dir>/step_<N>/arrays.npz + meta.msgpack, written to a tmp dir and
atomically renamed, so a crash mid-save never corrupts the latest
checkpoint. `latest_step` scans for complete checkpoints only.

Elastic reshard: arrays are saved in host memory unsharded (single-process
container); on restore they can be re-placed onto any mesh/sharding - a DP
size change (node loss -> smaller mesh) only changes the placement, and
the data pipeline's (seed, step) determinism keeps batches aligned. On a
multi-host deployment the same format holds per-host shard files; the
atomic-rename and resume logic is identical.

Async: save() can run in a background thread (device->host transfer done
synchronously first, serialization off the critical path).
"""
from __future__ import annotations

import os
import pathlib
import shutil
import threading
from typing import Any, Optional

import msgpack
import numpy as np
import jax


def _flatten(tree) -> tuple[dict[str, np.ndarray], dict[str, str]]:
    """Returns (arrays, dtypes). Non-npz dtypes (bfloat16 etc.) are stored
    as raw uint16/uint8 views with the true dtype recorded separately."""
    flat, dtypes = {}, {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = np.asarray(leaf)
        dtypes[key] = str(arr.dtype)
        if arr.dtype.kind not in "fiub" or str(arr.dtype) == "bfloat16":
            arr = arr.view(np.uint8).reshape(arr.shape + (-1,)) \
                if arr.dtype.itemsize != 2 else arr.view(np.uint16)
        flat[key] = arr
    return flat, dtypes


def _unflatten_into(template, arrays: dict[str, np.ndarray],
                    dtypes: dict[str, str]):
    import ml_dtypes
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        if key not in arrays:
            raise KeyError(f"checkpoint missing array {key}")
        arr = arrays[key]
        want = dtypes.get(key, str(arr.dtype))
        if str(arr.dtype) != want:   # stored as a raw view
            arr = arr.view(np.dtype(want) if want != "bfloat16"
                           else ml_dtypes.bfloat16)
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs "
                f"template {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save(ckpt_dir: str | pathlib.Path, step: int, tree, meta: Optional[dict]
         = None, async_: bool = False) -> threading.Thread | None:
    """Atomically write checkpoint for `step`."""
    ckpt_dir = pathlib.Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    flat, dtypes = _flatten(tree)  # device -> host happens synchronously

    def _write():
        tmp = ckpt_dir / f".tmp_step_{step}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir()
        np.savez(tmp / "arrays.npz", **flat)
        (tmp / "meta.msgpack").write_bytes(
            msgpack.packb({"step": step, "__dtypes__": dtypes,
                           **(meta or {})}))
        final = ckpt_dir / f"step_{step}"
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)

    if async_:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    _write()
    return None


def latest_step(ckpt_dir: str | pathlib.Path) -> Optional[int]:
    ckpt_dir = pathlib.Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = []
    for d in ckpt_dir.iterdir():
        if d.name.startswith("step_") and (d / "meta.msgpack").exists() \
                and (d / "arrays.npz").exists():
            steps.append(int(d.name.split("_")[1]))
    return max(steps) if steps else None


def restore(ckpt_dir: str | pathlib.Path, template, step: Optional[int]
            = None) -> tuple[Any, dict]:
    """Restore into the structure/shapes of `template`; returns (tree, meta).

    `template` may carry any sharding; arrays are host numpy and will be
    placed according to downstream jit/device_put - this is what makes a
    DP-size change on restore ("elastic reshard") transparent.
    """
    ckpt_dir = pathlib.Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = ckpt_dir / f"step_{step}"
    arrays = dict(np.load(d / "arrays.npz"))
    meta = msgpack.unpackb((d / "meta.msgpack").read_bytes())
    dtypes = meta.pop("__dtypes__", {})
    return _unflatten_into(template, arrays, dtypes), meta
