"""Deterministic synthetic LM data pipeline.

Generates a reproducible token stream with learnable structure (a mixture
of n-gram-ish patterns) so that short training runs show decreasing loss.
Host-sharded: each data-parallel host slice draws only its own shard of
the global batch (shard_id / num_shards), deterministically from
(seed, step), so restarts resume exactly and elastic reshards stay
deterministic.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    pattern_order: int = 3      # learnable markov-ish order


class SyntheticLM:
    def __init__(self, cfg: DataConfig, shard_id: int = 0,
                 num_shards: int = 1):
        if cfg.global_batch % num_shards:
            raise ValueError("global_batch must divide by num_shards")
        self.cfg = cfg
        self.shard_id = shard_id
        self.num_shards = num_shards
        self.local_batch = cfg.global_batch // num_shards
        # A fixed random transition table gives the stream structure a
        # model can learn (deterministic in the seed).
        rng = np.random.default_rng(cfg.seed)
        self._table = rng.integers(
            0, cfg.vocab_size,
            size=(min(cfg.vocab_size, 4096), 8)).astype(np.int32)

    def batch(self, step: int) -> dict:
        """Returns {tokens (B_local, S), labels} for this shard at `step`."""
        cfg = self.cfg
        out = np.empty((self.local_batch, cfg.seq_len + 1), np.int32)
        for i in range(self.local_batch):
            gidx = self.shard_id * self.local_batch + i
            rng = np.random.default_rng(
                (cfg.seed * 1_000_003 + step) * 65_521 + gidx)
            seq = np.empty(cfg.seq_len + 1, np.int32)
            seq[0] = rng.integers(0, cfg.vocab_size)
            noise = rng.random(cfg.seq_len)
            jumps = rng.integers(0, cfg.vocab_size, cfg.seq_len)
            for t in range(1, cfg.seq_len + 1):
                prev = seq[t - 1] % self._table.shape[0]
                choice = self._table[prev, t % 8]
                seq[t] = choice if noise[t - 1] < 0.8 else jumps[t - 1]
            out[i] = seq
        return {"tokens": out[:, :-1], "labels": out[:, 1:]}
