"""Fault-scenario grids for the sweep engine.

A ScenarioSpec is a fully-resolved, hashable description of one AllReduce
under one degradation pattern: cluster shape (p, g), pipeline depth k, vector
length n, and the per-rank slowdown vector. The generators below expand the
paper's four hand-picked figures into thousands of scenarios across five
families:

  healthy     - no degradation (ring baseline sanity / T0 calibration);
  single      - one straggler NIC, swept over p, ell and straggler position;
  multi       - m >= 2 stragglers with heterogeneous ell vectors and
                scattered placements (Appendix D's regime);
  multigpu    - g GPUs/server, one degraded server (PXN pools every GPU on
                the server through the slow NICs), both NVLink provisionings;
  correlated  - multigpu where the whole server is degraded hard (the
                "correlated server fault" case: ToR/egress loss hits every
                NIC on the box at once, ell drawn at the high end);
  replay      - time-varying failure timelines (NIC flaps, reroutes,
                recoveries) replayed through the simulator with mid-flight
                re-planning, from deterministic trace-shaped generators
                modeled on the Alibaba-GPU-2020 / AcmeTrace fault catalogs
                (PAPERS.md) plus miniature checked-in traces in ci/traces/;
  topology    - the registry schedules beyond ring/optcc (hierarchical,
                dbtree, torus2d) under healthy and degraded profiles, each
                scored against its own per-topology lower bound and against
                whatever `make_plan(algo="auto")` would have planned.

Grids are deterministic: the same (profile, seed) always yields the same
scenario list, which is what makes the sweep artifact reproducible and
diffable in CI. Randomized placements/ells use an explicit random.Random(seed)
stream, never global randomness.
"""
from __future__ import annotations

import dataclasses
import json
import os
import random
from typing import Iterator, Optional, Sequence

from repro.core.model import BandwidthProfile

# ell values the paper sweeps (fractions of NIC bandwidth retained:
# 7/8, 3/4, 5/8, 1/2, 3/8, 1/4).
PAPER_ELLS = (8 / 7, 4 / 3, 1.6, 2.0, 8 / 3, 4.0)


@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    """One point of a sweep grid. Frozen + tuple-valued so specs can be
    hashed, deduplicated, and pickled to worker processes."""

    name: str
    family: str           # healthy|single|multi|multigpu|correlated|replay
    p: int
    n: int
    k: int
    slowdown: tuple[float, ...]
    gpus_per_server: int = 1
    nvlink_mult: Optional[float] = None
    fill_bubbles: bool = True
    simulate_ring: bool = True        # also time the degraded ring (ICCL)
    # Failure timeline as (t, rank, ell) triples; t in units of the
    # scenario's fault-free optimum T0 so trace files are scale-free (the
    # engine multiplies by t0_fault_free(p, n, g) at run time). Empty =
    # static scenario. Tuple-of-tuples keeps the spec hashable.
    events: tuple[tuple[float, int, float], ...] = ()
    # Imperfect-detection config as sorted (key, value) pairs (hashable);
    # empty = the PR-8 zero-delay oracle controller (the replay family).
    # Keys mirror repro.detect.DetectorConfig / ControllerConfig; the
    # time-valued ones (probe_interval, latency, backoff_base) are in T0
    # units like `events` and are rescaled by the engine.
    detection: tuple[tuple[str, object], ...] = ()
    # Schedule-registry algorithm to plan ("auto" = the planner's OptCC-vs-
    # ring choice, the historical behavior). Non-"auto" scenarios (the
    # topology family) are additionally scored against the auto plan.
    algo: str = "auto"

    @property
    def policy(self) -> Optional[str]:
        """Controller policy for detection scenarios, else None."""
        d = dict(self.detection)
        return str(d["policy"]) if "policy" in d else None

    def profile(self) -> BandwidthProfile:
        return BandwidthProfile(p=self.p, slowdown=self.slowdown,
                                gpus_per_server=self.gpus_per_server,
                                nvlink_mult=self.nvlink_mult)

    @property
    def stragglers(self) -> tuple[int, ...]:
        return tuple(i for i, l in enumerate(self.slowdown) if l > 1.0)

    @property
    def max_ell(self) -> float:
        return max(self.slowdown)


def _slowdown(p: int, placed: dict[int, float]) -> tuple[float, ...]:
    sl = [1.0] * p
    for r, l in placed.items():
        sl[r] = l
    return tuple(sl)


def _seg_n(p: int, k: int, g: int = 1, unit: int = 16) -> int:
    """Vector length giving `unit` elements per (segment, section): keeps the
    flow count (and thus sweep wall time) proportional to p*k, independent of
    message size. Element-time is linear in n, so overhead ratios are
    n-invariant (benchmarks/fig8 b/d verify this)."""
    return g * k * max(p // g - 1, 1) * unit


# ----------------------------------------------------------------------------
# family generators
# ----------------------------------------------------------------------------

def gen_healthy(ps: Sequence[int], ks: Sequence[int]) -> Iterator[ScenarioSpec]:
    for p in ps:
        for k in ks:
            yield ScenarioSpec(name=f"healthy_p{p}_k{k}", family="healthy",
                               p=p, n=_seg_n(p, k), k=k,
                               slowdown=(1.0,) * p)


def gen_single(ps: Sequence[int], ks: Sequence[int],
               ells: Sequence[float] = PAPER_ELLS,
               positions: Sequence[float] = (0.0, 0.5)) -> Iterator[ScenarioSpec]:
    """Single straggler: sweep size, depth, severity and straggler position
    (positions are fractions of p; OptCC must be position-invariant)."""
    for p in ps:
        for k in ks:
            for ell in ells:
                for frac in positions:
                    pos = min(int(frac * p), p - 1)
                    yield ScenarioSpec(
                        name=f"single_p{p}_k{k}_l{ell:.3f}_r{pos}",
                        family="single", p=p, n=_seg_n(p, k), k=k,
                        slowdown=_slowdown(p, {pos: ell}))


def gen_multi(ps: Sequence[int], ks: Sequence[int],
              ell_sets: Sequence[tuple[float, ...]],
              rng: random.Random) -> Iterator[ScenarioSpec]:
    """m >= 2 stragglers with heterogeneous severities; placements drawn from
    the seeded stream (adjacent, spread, and random placements all occur)."""
    for p in ps:
        for k in ks:
            for ells in ell_sets:
                m = len(ells)
                if m >= p - 1:
                    continue
                placements = {
                    "adj": list(range(m)),
                    "spread": [(i * p) // m for i in range(m)],
                    "rand": sorted(rng.sample(range(p), m)),
                }
                for ptag, ranks in placements.items():
                    if len(set(ranks)) != m:
                        continue
                    ltag = "-".join(f"{l:.2f}" for l in ells)
                    yield ScenarioSpec(
                        name=f"multi_p{p}_k{k}_l{ltag}_{ptag}",
                        family="multi", p=p, n=_seg_n(p, k), k=k,
                        slowdown=_slowdown(p, dict(zip(ranks, ells))))


def gen_multigpu(gs: Sequence[int], qs: Sequence[int], ks: Sequence[int],
                 ells: Sequence[float],
                 nvlink_mults: Sequence[Optional[float]] = (None, 12.0),
                 family: str = "multigpu") -> Iterator[ScenarioSpec]:
    """One degraded server with g GPUs behind its NIC pool. `correlated` is
    the same topology tagged separately and driven at high ell (whole-box
    ToR/egress faults rather than a single flaky NIC)."""
    for g in gs:
        for q in qs:
            p = g * q
            for k in ks:
                for ell in ells:
                    for nv in nvlink_mults:
                        nvtag = "nvmin" if nv is None else f"nv{nv:g}"
                        sl = {r: ell for r in range(g)}  # server 0 degraded
                        yield ScenarioSpec(
                            name=f"{family}_g{g}_q{q}_k{k}_l{ell:.3f}_{nvtag}",
                            family=family, p=p, n=_seg_n(p, k, g), k=k,
                            slowdown=_slowdown(p, sl), gpus_per_server=g,
                            nvlink_mult=nv,
                            # Degraded-ring baseline is meaningful but slow to
                            # simulate with NVLink phases; keep it for the
                            # smoke-sized grids only (q <= 8).
                            simulate_ring=(q <= 8))


def gen_random_single_multi(count: int, ps: Sequence[int],
                            ks: Sequence[int],
                            rng: random.Random) -> Iterator[ScenarioSpec]:
    """Fill the tail of the grid with randomized-but-reproducible scenarios:
    m in [1, 4] stragglers, ell in [1.28, 4], random placement. These catch
    regime boundaries the hand grids skip (ell just under 2, near-coincident
    stragglers, m close to p/2)."""
    for i in range(count):
        p = rng.choice(list(ps))
        k = rng.choice(list(ks))
        m = rng.randint(1, min(4, p // 2 - 1))
        ranks = rng.sample(range(p), m)
        placed = {}
        for r in ranks:
            # Bandwidth retained uniform in [1/4, 3/4] -> ell in [4/3, 4].
            # The floor keeps the tail inside the regime where OptCC
            # dominates the degraded ring at smoke-grid pipeline depths
            # (below ell ~1.45 at k=12 the ring's convoy-effect jitter makes
            # the head-to-head comparison noisy in isolated ell pockets; the
            # hand grids still cover ell = 8/7 and 4/3 there).
            retained = rng.uniform(0.25, 0.75)
            placed[r] = 1.0 / retained
        family = "single" if m == 1 else "multi"
        yield ScenarioSpec(
            name=f"rand{i:04d}_p{p}_k{k}_m{m}",
            family=family, p=p, n=_seg_n(p, k), k=k,
            slowdown=_slowdown(p, placed))


# ----------------------------------------------------------------------------
# replay family: time-varying failure timelines
# ----------------------------------------------------------------------------
#
# Event times are in units of the scenario's fault-free optimum T0 (the
# engine rescales), so the same trace shape is meaningful at every (p, n, k).
# Shapes are modeled on what the public GPU-cluster fault catalogs show
# (Alibaba-GPU-2020, AcmeTrace/Kalos; see the R2CCL entry in PAPERS.md):
# NIC/link flaps that clear within the collective, reroutes that move the
# congestion to another rank, and mid-collective recoveries of a straggler
# that was present at launch.

def gen_replay_recovery(ps: Sequence[int], ks: Sequence[int],
                        ells: Sequence[float] = (2.0, 4.0),
                        rec_fracs: Sequence[float] = (0.25, 0.5)
                        ) -> Iterator[ScenarioSpec]:
    """Straggler present at t=0 recovers mid-collective. The no-replan
    schedule keeps pacing itself for the vanished straggler (slotted release
    times), so these are the scenarios where mid-flight re-planning wins."""
    for p in ps:
        for k in ks:
            for ell in ells:
                for frac in rec_fracs:
                    yield ScenarioSpec(
                        name=f"replay_recovery_p{p}_k{k}_l{ell:.3f}_t{frac:g}",
                        family="replay", p=p, n=_seg_n(p, k), k=k,
                        slowdown=(1.0,) * p, simulate_ring=False,
                        events=((0.0, 0, ell), (frac, 0, 1.0)))


def gen_replay_flap(ps: Sequence[int], ks: Sequence[int],
                    ells: Sequence[float] = (2.0, 8 / 3)
                    ) -> Iterator[ScenarioSpec]:
    """Healthy launch; one NIC flaps down/up twice mid-collective (the
    transient-congestion shape OptiReduce attributes the p99 tail to)."""
    for p in ps:
        for k in ks:
            for ell in ells:
                r = p // 2
                yield ScenarioSpec(
                    name=f"replay_flap_p{p}_k{k}_l{ell:.3f}",
                    family="replay", p=p, n=_seg_n(p, k), k=k,
                    slowdown=(1.0,) * p, simulate_ring=False,
                    events=((0.15, r, ell), (0.35, r, 1.0),
                            (0.55, r, ell), (0.75, r, 1.0)))


def gen_replay_reroute(ps: Sequence[int], ks: Sequence[int],
                       ells: Sequence[float] = (2.0,)
                       ) -> Iterator[ScenarioSpec]:
    """Congestion moves: the launch straggler clears but the rerouted
    traffic degrades a different rank at the same instant."""
    for p in ps:
        for k in ks:
            for ell in ells:
                b = p // 2
                yield ScenarioSpec(
                    name=f"replay_reroute_p{p}_k{k}_l{ell:.3f}",
                    family="replay", p=p, n=_seg_n(p, k), k=k,
                    slowdown=(1.0,) * p, simulate_ring=False,
                    events=((0.0, 0, ell), (0.4, 0, 1.0), (0.4, b, ell)))


def gen_replay_const(ps: Sequence[int], ks: Sequence[int],
                     ells: Sequence[float] = (2.0,)
                     ) -> Iterator[ScenarioSpec]:
    """Constant timelines: the only event is at t=0, so the replay must be
    IEEE-754-identical to its static single-straggler twin (same p/n/k,
    straggler at rank 0) - tests/test_replay.py pins exactly that against
    the artifact."""
    for p in ps:
        for k in ks:
            for ell in ells:
                yield ScenarioSpec(
                    name=f"replay_const_p{p}_k{k}_l{ell:.3f}",
                    family="replay", p=p, n=_seg_n(p, k), k=k,
                    slowdown=(1.0,) * p, simulate_ring=False,
                    events=((0.0, 0, ell),))


# Checked-in miniature traces (ci/traces/*.json). Times in T0 units, ranks
# taken modulo p at expansion time. Resolution order: $REPRO_TRACES_DIR,
# then the repo-relative ci/traces next to the src/ layout.
_REPO_TRACES = os.path.normpath(os.path.join(
    os.path.dirname(__file__), "..", "..", "..", "ci", "traces"))


def traces_dir() -> str:
    return os.environ.get("REPRO_TRACES_DIR", _REPO_TRACES)


def load_trace(path: str) -> dict:
    """Load + validate one trace file: {"name", "events": [[t, rank, ell]...],
    optional "description"/"source"}. Raises ValueError on malformed files -
    a trace that silently loads as empty would weaken the CI gate."""
    with open(path) as f:
        obj = json.load(f)
    name = obj.get("name")
    events = obj.get("events")
    if not isinstance(name, str) or not name:
        raise ValueError(f"{path}: trace needs a non-empty string 'name'")
    if not isinstance(events, list) or not events:
        raise ValueError(f"{path}: trace needs a non-empty 'events' list")
    for i, e in enumerate(events):
        if (not isinstance(e, list) or len(e) != 3
                or not all(isinstance(x, (int, float)) for x in e)):
            raise ValueError(f"{path}: events[{i}] must be [t, rank, ell]")
        t, rank, ell = e
        if t < 0 or ell < 1.0 or int(rank) != rank or rank < 0:
            raise ValueError(f"{path}: events[{i}] out of range: {e}")
    return obj


def gen_replay_traces(ps: Sequence[int], ks: Sequence[int],
                      directory: Optional[str] = None
                      ) -> Iterator[ScenarioSpec]:
    """One scenario per (checked-in trace, p, k). Missing directory yields
    nothing (the grid stays valid outside a repo checkout); malformed trace
    files raise."""
    d = traces_dir() if directory is None else directory
    if not os.path.isdir(d):
        return
    for fname in sorted(os.listdir(d)):
        if not fname.endswith(".json"):
            continue
        tr = load_trace(os.path.join(d, fname))
        for p in ps:
            for k in ks:
                events = tuple((float(t), int(rank) % p, float(ell))
                               for t, rank, ell in tr["events"])
                yield ScenarioSpec(
                    name=f"replay_trace_{tr['name']}_p{p}_k{k}",
                    family="replay", p=p, n=_seg_n(p, k), k=k,
                    slowdown=(1.0,) * p, simulate_ring=False,
                    events=events)


def gen_replay(ps: Sequence[int], ks: Sequence[int],
               ells: Sequence[float] = (2.0, 4.0)) -> list[ScenarioSpec]:
    """The whole replay family for a (ps, ks) block: generator shapes plus
    every checked-in trace."""
    specs: list[ScenarioSpec] = []
    specs += gen_replay_recovery(ps, ks, ells=ells)
    specs += gen_replay_flap(ps, ks)
    specs += gen_replay_reroute(ps, ks)
    specs += gen_replay_const(ps, ks)
    specs += gen_replay_traces(ps, ks)
    return specs


# ----------------------------------------------------------------------------
# detection family: imperfect detectors + controller policies
# ----------------------------------------------------------------------------
#
# Each scenario replays one of the checked-in fault traces (the flap /
# recovery / reroute-cascade shapes) through an *imperfect* detector -
# probe cadence x estimation noise x FP/FN rates - under one controller
# policy (immediate / debounce / backoff), and is scored against the PR-8
# zero-delay oracle on the same trace (`overhead_vs_oracle`). Falls back to
# the equivalent generator shapes when ci/traces is absent (a grid built
# outside a repo checkout must still carry the family - it is CI-gated).

# (name, events) fallbacks mirroring ci/traces/*.json shapes.
_DETECTION_FALLBACK_BASES = (
    ("nic_flap", ((0.1, 3, 2.0), (0.22, 3, 1.0), (0.4, 3, 2.0),
                  (0.48, 3, 1.0), (0.66, 3, 1.6), (0.8, 3, 1.0))),
    ("reroute_cascade", ((0.0, 0, 8 / 3), (0.3, 0, 1.0), (0.3, 2, 1.6),
                         (0.3, 5, 1.6), (0.7, 2, 1.0), (0.7, 5, 1.0))),
    ("straggler_recovery", ((0.0, 1, 4.0), (0.35, 1, 1.0))),
)


def _detection_bases(p: int) -> list[tuple[str, tuple]]:
    """(name, events) per checked-in trace, ranks wrapped modulo p."""
    d = traces_dir()
    if not os.path.isdir(d):
        bases = list(_DETECTION_FALLBACK_BASES)
    else:
        bases = []
        for fname in sorted(os.listdir(d)):
            if fname.endswith(".json"):
                tr = load_trace(os.path.join(d, fname))
                bases.append((tr["name"], tuple(
                    (float(t), int(r), float(l)) for t, r, l in tr["events"])))
    return [(name, tuple((t, r % p, l) for t, r, l in events))
            for name, events in bases]


def gen_detection(ps: Sequence[int], ks: Sequence[int],
                  probe_intervals: Sequence[float] = (0.02, 0.06),
                  noises: Sequence[float] = (0.0, 0.15),
                  fpfns: Sequence[tuple[float, float]] = ((0.0, 0.0),
                                                          (0.02, 0.05)),
                  policies: Sequence[str] = ("immediate", "debounce",
                                             "backoff"),
                  latency: float = 0.01,
                  quant: float = 0.25) -> Iterator[ScenarioSpec]:
    """Detection grid: traces x probe interval x noise x (FP, FN) x policy.

    All detector times are in T0 units (scale-free, like trace events).
    Each detector combo gets its own deterministic seed so FP/FN draws
    differ across combos but never across runs."""
    for p in ps:
        bases = _detection_bases(p)
        for k in ks:
            for name, events in bases:
                combo = 0
                for pi in probe_intervals:
                    for nz in noises:
                        for fp, fn in fpfns:
                            combo += 1
                            for policy in policies:
                                det = (
                                    ("fn_rate", fn),
                                    ("fp_rate", fp),
                                    ("latency", latency),
                                    ("noise", nz),
                                    ("policy", policy),
                                    ("probe_interval", pi),
                                    ("quant", quant),
                                    ("seed", combo),
                                )
                                yield ScenarioSpec(
                                    name=(f"detect_{name}_p{p}_k{k}"
                                          f"_pi{pi:g}_nz{nz:g}_fp{fp:g}"
                                          f"_fn{fn:g}_{policy}"),
                                    family="detection", p=p,
                                    n=_seg_n(p, k), k=k,
                                    slowdown=(1.0,) * p,
                                    simulate_ring=False,
                                    events=events, detection=det)


def gen_topology(ps: Sequence[int] = (8, 16), ks: Sequence[int] = (12,),
                 ells: Sequence[float] = (1.6, 2.0, 4.0),
                 hier_gs: Sequence[int] = (2, 4),
                 hier_qs: Sequence[int] = (4, 8),
                 hier_ells: Sequence[float] = (2.0, 4.0)
                 ) -> Iterator[ScenarioSpec]:
    """Topology family: every registry schedule beyond ring/optcc, under
    healthy and straggler profiles. dbtree/torus2d run on flat (g=1)
    clusters with a mid-ring straggler; hierarchical runs on multi-GPU
    servers with server 0 degraded (PXN: all its NICs slow). The engine
    also plans `algo="auto"` on the same profile, so each scenario is
    scored both against its own lower bound (optcc_vs_lb) and against the
    planner's choice (overhead_vs_auto). Fully deterministic - no rng."""
    for algo in ("dbtree", "torus2d"):
        for p in ps:
            for k in ks:
                n = _seg_n(p, k)
                yield ScenarioSpec(name=f"topo_{algo}_healthy_p{p}_k{k}",
                                   family="topology", p=p, n=n, k=k,
                                   slowdown=(1.0,) * p,
                                   simulate_ring=False, algo=algo)
                for ell in ells:
                    yield ScenarioSpec(
                        name=f"topo_{algo}_single_p{p}_k{k}_l{ell:g}",
                        family="topology", p=p, n=n, k=k,
                        slowdown=_slowdown(p, {p // 2: ell}),
                        simulate_ring=False, algo=algo)
    for g in hier_gs:
        for q in hier_qs:
            p = g * q
            for k in ks:
                n = _seg_n(p, k, g)
                yield ScenarioSpec(
                    name=f"topo_hier_healthy_g{g}_q{q}_k{k}",
                    family="topology", p=p, n=n, k=k,
                    slowdown=(1.0,) * p, gpus_per_server=g,
                    simulate_ring=False, algo="hierarchical")
                for ell in hier_ells:
                    yield ScenarioSpec(
                        name=f"topo_hier_g{g}_q{q}_k{k}_l{ell:g}",
                        family="topology", p=p, n=n, k=k,
                        slowdown=_slowdown(p, {r: ell for r in range(g)}),
                        gpus_per_server=g,
                        simulate_ring=False, algo="hierarchical")


# ----------------------------------------------------------------------------
# named grids
# ----------------------------------------------------------------------------

def smoke_grid(seed: int = 0) -> list[ScenarioSpec]:
    """CI-sized: >= 200 scenarios, seconds of CPU. Small p; k deep enough
    (>= 12) to amortize pipeline fill, so the paper's OptCC-beats-degraded-
    ring claim holds on every ell <= 2 scenario (tests/test_sweeps.py gates
    on exactly that). The shallow-k fill-cost regime lives in full_grid."""
    rng = random.Random(seed)
    specs: list[ScenarioSpec] = []
    specs += gen_healthy(ps=(4, 8, 16), ks=(12, 16))
    specs += gen_single(ps=(4, 8, 16), ks=(12, 16))
    specs += gen_multi(
        ps=(8, 16), ks=(12,),
        ell_sets=((4 / 3, 8 / 7), (2.0, 4 / 3), (2.0, 2.0),
                  (8 / 3, 1.6, 8 / 7)),
        rng=rng)
    specs += gen_multigpu(gs=(2, 4), qs=(4, 8), ks=(12,),
                          ells=(8 / 7, 2.0))
    specs += gen_multigpu(gs=(2, 4), qs=(4,), ks=(12,),
                          ells=(8 / 3, 4.0), nvlink_mults=(12.0,),
                          family="correlated")
    specs += gen_random_single_multi(count=96, ps=(8, 12, 16), ks=(16,),
                                     rng=rng)
    specs += gen_replay(ps=(8, 16), ks=(12,))
    specs += gen_detection(ps=(8,), ks=(12,))
    specs += gen_topology()
    return _dedup(specs)


def full_grid(seed: int = 0) -> list[ScenarioSpec]:
    """Nightly-sized: thousands of scenarios up to p=64 at every depth, plus
    a paper-scale p=1024 block (Section 4.3 runs at p=1024; the vectorized
    generator + simulator make ~8M-flow scenarios minutes, not hours)."""
    rng = random.Random(seed)
    specs: list[ScenarioSpec] = []
    specs += gen_healthy(ps=(4, 8, 16, 32, 64), ks=(4, 16, 32))
    specs += gen_single(ps=(4, 8, 16, 32, 64), ks=(4, 16, 32),
                        positions=(0.0, 0.25, 0.5))
    # Paper-scale block: p=256 and p=1024 single stragglers. One straggler
    # position (OptCC is position-invariant; the small-p blocks above sweep
    # positions) and shallow k to keep flow counts ~p^2 k bounded.
    specs += gen_healthy(ps=(256, 1024), ks=(4,))
    specs += gen_single(ps=(256, 1024), ks=(4,),
                        ells=(8 / 7, 2.0, 4.0), positions=(0.5,))
    specs += gen_multi(
        ps=(8, 16, 32, 64), ks=(4, 16),
        ell_sets=((4 / 3, 8 / 7), (2.0, 4 / 3), (2.0, 2.0), (4.0, 2.0),
                  (8 / 3, 1.6, 8 / 7), (2.0, 2.0, 2.0, 2.0)),
        rng=rng)
    specs += gen_multigpu(gs=(2, 4, 8), qs=(4, 8, 16), ks=(4, 12),
                          ells=PAPER_ELLS)
    # ks disjoint from the multigpu block above, or _dedup would fold the
    # whole-box fault family into it (same physical profiles otherwise).
    specs += gen_multigpu(gs=(4, 8), qs=(4, 8), ks=(6,),
                          ells=(8 / 3, 4.0), nvlink_mults=(None, 12.0),
                          family="correlated")
    specs += gen_random_single_multi(count=400, ps=(8, 16, 32), ks=(4, 16),
                                     rng=rng)
    specs += gen_replay(ps=(8, 16, 32), ks=(4, 16),
                        ells=(8 / 7, 2.0, 8 / 3, 4.0))
    specs += gen_detection(ps=(8, 16), ks=(12,),
                           probe_intervals=(0.01, 0.03, 0.08),
                           noises=(0.0, 0.15, 0.3),
                           fpfns=((0.0, 0.0), (0.02, 0.05), (0.08, 0.1)))
    specs += gen_topology(ps=(8, 16, 32, 64), ks=(4, 12),
                          hier_gs=(2, 4, 8), hier_qs=(4, 8, 16))
    return _dedup(specs)


GRIDS = {"smoke": smoke_grid, "full": full_grid}


def _dedup(specs: Sequence[ScenarioSpec]) -> list[ScenarioSpec]:
    seen: set[tuple] = set()
    out = []
    for s in specs:
        key = (s.p, s.n, s.k, s.slowdown, s.gpus_per_server, s.nvlink_mult,
               s.fill_bubbles, s.events, s.detection, s.algo)
        if key in seen:
            continue
        seen.add(key)
        out.append(s)
    return out
