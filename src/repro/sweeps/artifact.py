"""Versioned JSON perf artifact (BENCH_sweep.json) + schema/threshold checks.

The artifact is the sweep's single output: per-scenario overheads plus
p50/p99 summaries, written with canonical serialization (sorted keys, fixed
separators) so that two runs of the same grid with `measure_latency=False`
are byte-identical - CI diffs artifacts, and regression gating reads the
summary block against a checked-in thresholds file.

Schema versioning: bump SCHEMA when a field changes meaning or disappears;
adding fields is backward-compatible (validators only check what they know).
`load_artifact` migrates v1 artifacts in place (see _migrate_v1), so readers
only ever see the current schema.

optcc-sweep/2 (vs /1):
  * top-level ``telemetry`` bool; when true every scenario carries a
    ``stage_breakdown`` ({stage: element-time} summing to t_optcc) and each
    summary group a ``stages`` block with per-stage overhead percentiles;
  * wall-clock fields (``gen_ms``/``sim_ms``, summary ``gen_ms_p50/p99``)
    are null on deterministic runs instead of 0.0 - unmeasured is not zero,
    and the old 0.0 silently satisfied every latency threshold.

optcc-sweep/3 (vs /2):
  * replay-family scenarios (time-varying failure timelines) carry
    ``events`` ([t, rank, ell] triples, t in units of T0), ``t_noreplan`` /
    ``overhead_noreplan`` (the initial plan ridden through the whole
    timeline - the baseline re-planning is scored against) and ``replans``
    (splices made). For these rows t_optcc is the makespan the mid-flight
    re-planning controller *adopts* (min of the replanned chain and the
    no-replan run), so overhead_optcc / optcc_vs_lb score the system's
    actual behavior; the stage_breakdown attributes the no-replan run and
    sums to t_noreplan for replay rows;
  * summary groups containing replay scenarios add
    ``overhead_noreplan_p50/p99/max``;
  * thresholds gain a ``families`` block ({family: {metric_max: limit,
    min_scenarios: N}}); a gated family missing from the artifact fails
    loudly (a grid regression must not silently pass).

optcc-sweep/4 (vs /3):
  * detection-family scenarios (imperfect detector + controller policy over
    a replay timeline) carry every replay field plus ``policy``,
    ``detection`` (the detector/controller parameters, times in T0 units),
    ``t_oracle`` / ``overhead_vs_oracle`` (the same timeline under the PR-8
    zero-delay perfect-knowledge controller - the denominator that prices
    detection imperfection), ``false_replans``, ``suppressed``,
    ``detect_lag_mean`` / ``detect_lag_max`` (null when nothing was
    detected) and ``detect_missed``;
  * summary groups containing detection scenarios add
    ``overhead_vs_oracle_p50/p99/max`` and ``false_replans_total``, and the
    summary block gains ``by_policy`` (detection records grouped by
    controller policy);
  * top-level ``retries`` records how many worker chunks the sweep engine
    had to re-run after a crash/hang (null = unknown, from older artifacts).

optcc-sweep/5 (vs /4):
  * topology-family scenarios (an explicitly requested registry algorithm,
    `ScenarioSpec.algo != "auto"`) carry ``requested_algo``, ``t_auto``
    (the makespan `make_plan(algo="auto")` achieves on the identical
    profile) and ``overhead_vs_auto`` (= t_optcc / t_auto). For these rows
    t_optcc is the requested topology's simulated makespan and lower_bound
    its *per-topology* bound from the registry, so optcc_vs_lb scores the
    topology against its own floor;
  * ``summary.overall`` covers only planner-driven rows (no
    ``requested_algo``): topology rows are deliberately suboptimal
    baselines on profiles the planner would route elsewhere (a double
    binary tree is ~log p slower than T0 by design), and folding them into
    the overall percentiles would force loosening the tight regression
    gates that protect the auto path. Topology rows are summarized in
    ``summary.by_family.topology`` (gated via ``families.topology``) and
    the new ``summary.by_algo`` block (topology records grouped by
    requested algorithm, each adding ``overhead_vs_auto_p50/p99/max``).
"""
from __future__ import annotations

import json
from typing import Optional, Sequence

from repro.sweeps.engine import ScenarioResult
from repro.sweeps.stats import percentile, percentile_or_none

__all__ = ["SCHEMA", "THRESHOLDS_SCHEMA", "percentile", "scenario_record",
           "build_artifact", "canonical_bytes", "write_artifact",
           "load_artifact", "validate_artifact", "check_thresholds"]

SCHEMA = "optcc-sweep/5"
THRESHOLDS_SCHEMA = "optcc-sweep-thresholds/1"

_SCENARIO_REQUIRED = {
    "name": str, "family": str, "algo": str,
    "p": int, "k": int, "n": int, "gpus_per_server": int,
    "num_flows": int,
    "stragglers": list, "ells": list,
    "t0": float, "lower_bound": float, "t_optcc": float,
    "t_predicted": float,
    "overhead_optcc": float, "overhead_lb": float, "optcc_vs_lb": float,
}
# Wall-clock fields: numeric when measured, null on deterministic runs.
_SCENARIO_LATENCY = ("gen_ms", "sim_ms")

_SUMMARY_KEYS = ("count", "overhead_optcc_p50", "overhead_optcc_p99",
                 "overhead_optcc_max", "optcc_vs_lb_p50", "optcc_vs_lb_p99",
                 "optcc_vs_lb_max", "gen_ms_p50", "gen_ms_p99")


def _round(x: Optional[float], digits: int = 9) -> Optional[float]:
    # Fixed rounding keeps artifact bytes stable against float noise from
    # e.g. different summation orders in future parallel scoring.
    return None if x is None else round(float(x), digits)


def scenario_record(r: ScenarioResult, deterministic: bool = False) -> dict:
    s = r.spec
    rec = {
        "name": s.name,
        "family": s.family,
        "algo": r.algo,
        "p": s.p,
        "k": s.k,
        "n": s.n,
        "gpus_per_server": s.gpus_per_server,
        "nvlink_mult": s.nvlink_mult,
        "num_flows": r.num_flows,
        "stragglers": list(s.stragglers),
        "ells": [_round(s.slowdown[i]) for i in s.stragglers],
        "t0": _round(r.t0),
        "lower_bound": _round(r.lower_bound),
        "t_optcc": _round(r.t_optcc),
        "t_ring": _round(r.t_ring),
        "t_predicted": _round(r.t_predicted),
        "overhead_optcc": _round(r.overhead_optcc),
        "overhead_ring": _round(r.overhead_ring),
        "overhead_lb": _round(r.overhead_lb),
        "optcc_vs_lb": _round(r.optcc_vs_lb),
        # Unmeasured is null, not 0.0 (deterministic runs exclude wall
        # clock so artifacts are byte-identical; see schedgen_latency_ms).
        "gen_ms": None if deterministic else _round(r.gen_seconds * 1e3, 6),
        "sim_ms": None if deterministic else _round(r.sim_seconds * 1e3, 6),
    }
    if r.t_auto is not None:
        # Topology family: t_optcc above is the *requested* algorithm's
        # makespan and lower_bound its per-topology floor; t_auto is what
        # the planner's auto policy achieves on the identical profile.
        rec["requested_algo"] = r.requested_algo
        rec["t_auto"] = _round(r.t_auto)
        rec["overhead_vs_auto"] = _round(r.overhead_vs_auto)
    if r.t_noreplan is not None:
        # Replay family: t_optcc above is the re-planning controller's
        # adopted makespan; these are the no-replan baseline (the initial
        # plan ridden through the whole timeline) plus the timeline itself.
        rec["t_noreplan"] = _round(r.t_noreplan)
        rec["overhead_noreplan"] = _round(r.overhead_noreplan)
        rec["replans"] = r.replans
        rec["events"] = [[_round(t), rank, _round(ell)]
                         for t, rank, ell in s.events]
    if r.policy is not None:
        # Detection family: the replay fields above scored the *imperfect*
        # controller; these add the lens parameters and the oracle yardstick.
        rec["policy"] = r.policy
        rec["detection"] = {key: (_round(v) if isinstance(v, float) else v)
                            for key, v in s.detection}
        rec["t_oracle"] = _round(r.t_oracle)
        rec["overhead_vs_oracle"] = _round(r.overhead_vs_oracle)
        rec["false_replans"] = r.false_replans
        rec["suppressed"] = r.suppressed
        rec["detect_lag_mean"] = _round(r.detect_lag_mean)
        rec["detect_lag_max"] = _round(r.detect_lag_max)
        rec["detect_missed"] = r.detect_missed
    if r.stage_breakdown is not None:
        rec["stage_breakdown"] = {st: _round(v)
                                  for st, v in sorted(r.stage_breakdown.items())}
    return rec


def _stage_summary(records: Sequence[dict]) -> dict:
    """Per-stage critical-path overhead percentiles over the scenarios in
    which the stage appears (overhead = contribution / t0). `count` says how
    many scenarios that was - stages are not zero-filled across the grid."""
    per_stage: dict[str, list[float]] = {}
    for r in records:
        t0 = r["t0"]
        for st, v in (r.get("stage_breakdown") or {}).items():
            per_stage.setdefault(st, []).append(v / t0)
    return {st: {"count": len(vs),
                 "overhead_p50": _round(percentile(vs, 50)),
                 "overhead_p99": _round(percentile(vs, 99)),
                 "overhead_max": _round(max(vs))}
            for st, vs in sorted(per_stage.items())}


def _summarize(records: Sequence[dict], telemetry: bool = False) -> dict:
    ov = [r["overhead_optcc"] for r in records]
    vs = [r["optcc_vs_lb"] for r in records]
    gen = [r["gen_ms"] for r in records]
    out = {
        "count": len(records),
        "overhead_optcc_p50": _round(percentile(ov, 50)),
        "overhead_optcc_p99": _round(percentile(ov, 99)),
        "overhead_optcc_max": _round(max(ov)),
        "optcc_vs_lb_p50": _round(percentile(vs, 50)),
        "optcc_vs_lb_p99": _round(percentile(vs, 99)),
        "optcc_vs_lb_max": _round(max(vs)),
        "gen_ms_p50": _round(percentile_or_none(gen, 50), 6),
        "gen_ms_p99": _round(percentile_or_none(gen, 99), 6),
    }
    rep = [r["overhead_noreplan"] for r in records
           if "overhead_noreplan" in r]
    if rep:
        out["overhead_noreplan_p50"] = _round(percentile(rep, 50))
        out["overhead_noreplan_p99"] = _round(percentile(rep, 99))
        out["overhead_noreplan_max"] = _round(max(rep))
    aut = [r["overhead_vs_auto"] for r in records
           if "overhead_vs_auto" in r]
    if aut:
        out["overhead_vs_auto_p50"] = _round(percentile(aut, 50))
        out["overhead_vs_auto_p99"] = _round(percentile(aut, 99))
        out["overhead_vs_auto_max"] = _round(max(aut))
    orc = [r["overhead_vs_oracle"] for r in records
           if "overhead_vs_oracle" in r]
    if orc:
        out["overhead_vs_oracle_p50"] = _round(percentile(orc, 50))
        out["overhead_vs_oracle_p99"] = _round(percentile(orc, 99))
        out["overhead_vs_oracle_max"] = _round(max(orc))
        out["false_replans_total"] = sum(r["false_replans"] for r in records
                                         if "false_replans" in r)
    if telemetry:
        out["stages"] = _stage_summary(records)
    return out


def build_artifact(results: Sequence[ScenarioResult], profile: str,
                   seed: int, deterministic: bool,
                   schedgen_latency_ms: Optional[float] = None,
                   telemetry: bool = False,
                   retries: int = 0) -> dict:
    records = [scenario_record(r, deterministic=deterministic)
               for r in results]
    families = sorted({r["family"] for r in records})
    policies = sorted({r["policy"] for r in records if "policy" in r})
    algos = sorted({r["requested_algo"] for r in records
                    if "requested_algo" in r})
    # "overall" scores the planner-driven path only: topology rows request
    # a specific algorithm regardless of fit (dbtree on a straggler profile
    # is a deliberate baseline) and carry their own gates via
    # families.topology / by_algo; mixing them in would blunt the tight
    # overall regression thresholds. Degenerate topology-only grids keep a
    # non-empty overall block by falling back to all records.
    auto_records = [r for r in records if "requested_algo" not in r]
    summary = {
        "overall": _summarize(auto_records or records, telemetry),
        "by_family": {
            fam: _summarize([r for r in records if r["family"] == fam],
                            telemetry)
            for fam in families
        },
    }
    if policies:
        summary["by_policy"] = {
            pol: _summarize([r for r in records if r.get("policy") == pol],
                            telemetry)
            for pol in policies
        }
    if algos:
        summary["by_algo"] = {
            algo: _summarize([r for r in records
                              if r.get("requested_algo") == algo], telemetry)
            for algo in algos
        }
    return {
        "schema": SCHEMA,
        "profile": profile,
        "seed": seed,
        "deterministic": deterministic,
        "telemetry": telemetry,
        # Best-of-N descriptor-path re-planning latency at p=1024 (Section
        # 4.3's < 1 ms claim); None on deterministic runs, where wall-clock
        # measurements are excluded so artifacts stay byte-identical.
        "schedgen_latency_ms": _round(schedgen_latency_ms, 6),
        "scenario_count": len(records),
        # Worker chunks the engine re-ran after a crash/hang; deterministic
        # per grid only in the common 0 case, but retries don't perturb
        # scenario bytes (results are pure functions of specs either way).
        "retries": retries,
        "summary": summary,
        "scenarios": records,
    }


def canonical_bytes(artifact: dict) -> bytes:
    return json.dumps(artifact, sort_keys=True, separators=(",", ":"),
                      allow_nan=False).encode() + b"\n"


def write_artifact(artifact: dict, path: str) -> None:
    with open(path, "wb") as f:
        f.write(canonical_bytes(artifact))


def _reject_constant(name: str) -> float:
    raise ValueError(f"non-finite JSON constant {name!r} in artifact")


def _migrate_v1(obj: dict) -> dict:
    """In-place upgrade of an optcc-sweep/1 artifact to /2 semantics:
    no telemetry, and deterministic runs' 0.0 wall-clock placeholders become
    null (v1 wrote zeros for unmeasured latencies)."""
    obj["schema"] = "optcc-sweep/2"
    obj["telemetry"] = False
    if obj.get("deterministic"):
        for rec in obj.get("scenarios", ()):
            for key in _SCENARIO_LATENCY:
                rec[key] = None
        summary = obj.get("summary", {})
        groups = [summary.get("overall", {})]
        groups.extend(summary.get("by_family", {}).values())
        for stats in groups:
            stats["gen_ms_p50"] = stats["gen_ms_p99"] = None
    return obj


def _migrate_v2(obj: dict) -> dict:
    """optcc-sweep/2 -> /3: purely additive (replay fields are optional and
    a v2 artifact simply predates the replay family), so only the tag moves."""
    obj["schema"] = "optcc-sweep/3"
    return obj


def _migrate_v3(obj: dict) -> dict:
    """optcc-sweep/3 -> /4: detection fields are additive (a v3 artifact
    predates the detection family), but the engine's retry count was not
    recorded - null marks it unknown rather than claiming a clean 0."""
    obj["schema"] = "optcc-sweep/4"
    obj["retries"] = None
    return obj


def _migrate_v4(obj: dict) -> dict:
    """optcc-sweep/4 -> /5: purely additive (topology fields are optional
    and a v4 artifact predates the topology family; its overall summary
    already covers only planner-driven rows), so only the tag moves."""
    obj["schema"] = SCHEMA
    return obj


def load_artifact(path: str) -> dict:
    # NaN/Infinity would sail through every comparison in validation and
    # threshold gating (NaN > limit is False), turning the CI gate green on
    # corrupted data - reject them at parse time.
    with open(path, "rb") as f:
        obj = json.load(f, parse_constant=_reject_constant)
    if obj.get("schema") == "optcc-sweep/1":
        obj = _migrate_v1(obj)
    if obj.get("schema") == "optcc-sweep/2":
        obj = _migrate_v2(obj)
    if obj.get("schema") == "optcc-sweep/3":
        obj = _migrate_v3(obj)
    if obj.get("schema") == "optcc-sweep/4":
        obj = _migrate_v4(obj)
    return obj


# ----------------------------------------------------------------------------
# validation + regression gating
# ----------------------------------------------------------------------------

def validate_artifact(artifact: dict) -> list[str]:
    """Structural schema check; returns a list of problems (empty = valid)."""
    errs: list[str] = []
    if artifact.get("schema") != SCHEMA:
        errs.append(f"schema is {artifact.get('schema')!r}, want {SCHEMA!r}")
        return errs
    for key in ("profile", "seed", "scenario_count", "summary", "scenarios"):
        if key not in artifact:
            errs.append(f"missing top-level key {key!r}")
    if errs:
        return errs
    scenarios = artifact["scenarios"]
    telemetry = bool(artifact.get("telemetry"))
    if artifact["scenario_count"] != len(scenarios):
        errs.append(f"scenario_count {artifact['scenario_count']} != "
                    f"len(scenarios) {len(scenarios)}")
    names = set()
    for i, rec in enumerate(scenarios):
        rec_errs: list[str] = []
        for key, typ in _SCENARIO_REQUIRED.items():
            if key not in rec:
                rec_errs.append(f"scenario[{i}] missing {key!r}")
            elif typ is float:
                if not isinstance(rec[key], (int, float)):
                    rec_errs.append(f"scenario[{i}].{key} not numeric")
            elif not isinstance(rec[key], typ):
                rec_errs.append(f"scenario[{i}].{key} not {typ.__name__}")
        for key in _SCENARIO_LATENCY:
            if key not in rec:
                rec_errs.append(f"scenario[{i}] missing {key!r}")
            elif rec[key] is not None and not isinstance(rec[key],
                                                        (int, float)):
                rec_errs.append(f"scenario[{i}].{key} not numeric or null")
        if rec_errs:
            errs.extend(rec_errs)
            continue
        if rec["name"] in names:
            errs.append(f"duplicate scenario name {rec['name']!r}")
        names.add(rec["name"])
        if rec["t_optcc"] < rec["lower_bound"] * (1 - 1e-9):
            errs.append(f"{rec['name']}: t_optcc beats the lower bound")
        if rec["overhead_lb"] > rec["overhead_optcc"] * (1 + 1e-9):
            errs.append(f"{rec['name']}: overhead_lb > overhead_optcc")
        if rec["family"] in ("replay", "detection"):
            fam = rec["family"]
            if not isinstance(rec.get("t_noreplan"), (int, float)):
                errs.append(f"{rec['name']}: {fam} scenario lacks "
                            f"t_noreplan")
            elif not isinstance(rec.get("replans"), int) \
                    or rec["replans"] < 0:
                errs.append(f"{rec['name']}: {fam} scenario needs a "
                            f"non-negative int 'replans'")
            elif not isinstance(rec.get("events"), list) or not rec["events"]:
                errs.append(f"{rec['name']}: {fam} scenario lacks its "
                            f"'events' timeline")
            elif rec["t_optcc"] > rec["t_noreplan"] * (1 + 1e-9):
                errs.append(f"{rec['name']}: adopted t_optcc exceeds the "
                            f"no-replan baseline (the controller must take "
                            f"the better schedule)")
        elif "t_noreplan" in rec:
            errs.append(f"{rec['name']}: t_noreplan on a non-replay "
                        f"scenario")
        if rec["family"] == "detection":
            if not isinstance(rec.get("policy"), str):
                errs.append(f"{rec['name']}: detection scenario lacks its "
                            f"controller 'policy'")
            if not isinstance(rec.get("detection"), dict):
                errs.append(f"{rec['name']}: detection scenario lacks its "
                            f"'detection' parameter block")
            if not isinstance(rec.get("t_oracle"), (int, float)):
                errs.append(f"{rec['name']}: detection scenario lacks "
                            f"t_oracle")
            elif not isinstance(rec.get("overhead_vs_oracle"), (int, float)):
                errs.append(f"{rec['name']}: detection scenario lacks "
                            f"overhead_vs_oracle")
            for key in ("false_replans", "suppressed", "detect_missed"):
                if not isinstance(rec.get(key), int) or rec[key] < 0:
                    errs.append(f"{rec['name']}: detection scenario needs a "
                                f"non-negative int {key!r}")
            for key in ("detect_lag_mean", "detect_lag_max"):
                if key not in rec:
                    errs.append(f"{rec['name']}: detection scenario "
                                f"missing {key!r}")
                elif rec[key] is not None and not isinstance(rec[key],
                                                             (int, float)):
                    errs.append(f"{rec['name']}.{key} not numeric or null")
        elif "policy" in rec:
            errs.append(f"{rec['name']}: policy on a non-detection scenario")
        if rec["family"] == "topology":
            if not isinstance(rec.get("requested_algo"), str):
                errs.append(f"{rec['name']}: topology scenario lacks "
                            f"requested_algo")
            if not isinstance(rec.get("t_auto"), (int, float)):
                errs.append(f"{rec['name']}: topology scenario lacks t_auto")
            elif not isinstance(rec.get("overhead_vs_auto"), (int, float)):
                errs.append(f"{rec['name']}: topology scenario lacks "
                            f"overhead_vs_auto")
        elif "t_auto" in rec or "requested_algo" in rec:
            errs.append(f"{rec['name']}: topology fields on a non-topology "
                        f"scenario")
        sb = rec.get("stage_breakdown")
        if telemetry:
            # The tentpole invariant, enforced on every telemetry artifact:
            # critical-path stage contributions account for the *entire*
            # simulated time (1e-6 relative absorbs the 9-digit rounding).
            # Replay rows attribute the no-replan run, so they sum to
            # t_noreplan; everything else sums to t_optcc.
            if not isinstance(sb, dict) or not sb:
                errs.append(f"{rec['name']}: telemetry artifact lacks "
                            f"stage_breakdown")
            else:
                ref_key = "t_noreplan" if "t_noreplan" in rec else "t_optcc"
                ref = rec[ref_key]
                total = sum(sb.values())
                if abs(total - ref) > 1e-6 * max(ref, 1.0):
                    errs.append(
                        f"{rec['name']}: stage_breakdown sums to "
                        f"{total:.9g}, {ref_key} is {ref:.9g}")
        elif sb is not None:
            errs.append(f"{rec['name']}: stage_breakdown present but "
                        f"telemetry is off")
    summary = artifact["summary"]
    if any(rec.get("family") == "detection" for rec in scenarios) \
            and "by_policy" not in summary:
        errs.append("artifact has detection scenarios but no "
                    "summary.by_policy block")
    if any("requested_algo" in rec for rec in scenarios) \
            and "by_algo" not in summary:
        errs.append("artifact has topology scenarios but no "
                    "summary.by_algo block")
    for group, stats in [("overall", summary.get("overall", {}))] + \
            sorted(summary.get("by_family", {}).items()) + \
            sorted(summary.get("by_policy", {}).items()) + \
            sorted(summary.get("by_algo", {}).items()):
        for key in _SUMMARY_KEYS:
            if key not in stats:
                errs.append(f"summary[{group}] missing {key!r}")
        if telemetry and "stages" not in stats:
            errs.append(f"summary[{group}] missing 'stages' block")
    return errs


def check_thresholds(artifact: dict, thresholds: dict) -> list[str]:
    """Regression gate: compare the artifact's summary against a checked-in
    thresholds file. Returns failures (empty = pass)."""
    fails: list[str] = []
    if thresholds.get("schema") != THRESHOLDS_SCHEMA:
        fails.append(f"thresholds schema is {thresholds.get('schema')!r}, "
                     f"want {THRESHOLDS_SCHEMA!r}")
        return fails
    overall = artifact["summary"]["overall"]
    checks = [
        ("overhead_optcc_p99", "p99 OptCC overhead vs fault-free T0"),
        ("overhead_optcc_max", "max OptCC overhead vs fault-free T0"),
        ("optcc_vs_lb_p99", "p99 OptCC time vs information-theoretic bound"),
        ("optcc_vs_lb_max", "max OptCC time vs information-theoretic bound"),
    ]
    for key, label in checks:
        limit = thresholds.get(f"{key}_max")
        if limit is None:
            continue
        got = overall[key]
        if got > limit:
            fails.append(f"{label}: {got:.6g} > limit {limit:.6g} ({key})")
    # Per-stage gates: {stage: p99 overhead limit}. A thresholds file that
    # names stages demands a telemetry artifact - a sweep run without
    # --telemetry must fail loudly, not skip the gate.
    stage_limits = thresholds.get("stage_overhead_p99_max") or {}
    if stage_limits:
        stages = overall.get("stages")
        if stages is None:
            fails.append("thresholds gate per-stage overheads but the "
                         "artifact has no stage telemetry (run the sweep "
                         "with --telemetry)")
        else:
            for stage, limit in sorted(stage_limits.items()):
                st = stages.get(stage)
                if st is None:
                    fails.append(f"stage {stage!r} gated but absent from "
                                 f"the sweep's critical paths")
                    continue
                got = st["overhead_p99"]
                if got > limit:
                    fails.append(
                        f"critical-path p99 overhead of stage {stage}: "
                        f"{got:.6g} > limit {limit:.6g} "
                        f"(stage_overhead_p99_max.{stage})")
    # Per-family gates: {family: {"<metric>_max": limit, "min_scenarios": N}}.
    # A family named in the thresholds file MUST be present in the artifact -
    # a grid regression that silently drops a family (e.g. the replay
    # scenarios failing to generate) must fail the gate, not skip it.
    fam_limits = thresholds.get("families") or {}
    by_family = artifact["summary"].get("by_family", {})
    for fam, limits in sorted(fam_limits.items()):
        stats = by_family.get(fam)
        if stats is None:
            fails.append(f"family {fam!r} is threshold-gated but absent "
                         f"from the artifact (present: "
                         f"{sorted(by_family)}); the grid lost a scenario "
                         f"family")
            continue
        for key, limit in sorted(limits.items()):
            if key == "min_scenarios":
                if stats["count"] < limit:
                    fails.append(f"family {fam}: count {stats['count']} < "
                                 f"required {limit}")
                continue
            metric = key[:-4] if key.endswith("_max") else key
            got = stats.get(metric)
            if got is None:
                fails.append(f"family {fam}: summary lacks {metric!r} "
                             f"(gated by families.{fam}.{key})")
            elif got > limit:
                fails.append(f"family {fam}: {metric} {got:.6g} > limit "
                             f"{limit:.6g} (families.{fam}.{key})")
    min_scen = thresholds.get("min_scenarios")
    if min_scen is not None and artifact["scenario_count"] < min_scen:
        fails.append(f"scenario_count {artifact['scenario_count']} < "
                     f"required {min_scen}")
    lat_limit = thresholds.get("schedgen_latency_ms_max")
    if lat_limit is not None:
        lat = artifact.get("schedgen_latency_ms")
        # None = deterministic run (latency deliberately unmeasured); the
        # gate only fires on measured values.
        if lat is not None and lat > lat_limit:
            fails.append(f"schedule-generation latency at p=1024: "
                         f"{lat:.6g} ms > limit {lat_limit:.6g} ms "
                         f"(schedgen_latency_ms)")
    return fails
