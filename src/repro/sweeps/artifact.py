"""Versioned JSON perf artifact (BENCH_sweep.json) + schema/threshold checks.

The artifact is the sweep's single output: per-scenario overheads plus
p50/p99 summaries, written with canonical serialization (sorted keys, fixed
separators) so that two runs of the same grid with `measure_latency=False`
are byte-identical - CI diffs artifacts, and regression gating reads the
summary block against a checked-in thresholds file.

Schema versioning: bump SCHEMA when a field changes meaning or disappears;
adding fields is backward-compatible (validators only check what they know).
"""
from __future__ import annotations

import json
import math
from typing import Optional, Sequence

from repro.sweeps.engine import ScenarioResult

SCHEMA = "optcc-sweep/1"
THRESHOLDS_SCHEMA = "optcc-sweep-thresholds/1"

_SCENARIO_REQUIRED = {
    "name": str, "family": str, "algo": str,
    "p": int, "k": int, "n": int, "gpus_per_server": int,
    "num_flows": int,
    "stragglers": list, "ells": list,
    "t0": float, "lower_bound": float, "t_optcc": float,
    "t_predicted": float,
    "overhead_optcc": float, "overhead_lb": float, "optcc_vs_lb": float,
    "gen_ms": float, "sim_ms": float,
}

_SUMMARY_KEYS = ("count", "overhead_optcc_p50", "overhead_optcc_p99",
                 "overhead_optcc_max", "optcc_vs_lb_p50", "optcc_vs_lb_p99",
                 "optcc_vs_lb_max", "gen_ms_p50", "gen_ms_p99")


def percentile(values: Sequence[float], q: float) -> float:
    """Linear-interpolation percentile (numpy 'linear'), pure Python so the
    artifact bytes don't depend on the numpy version."""
    if not values:
        return math.nan
    xs = sorted(values)
    if len(xs) == 1:
        return xs[0]
    pos = (q / 100.0) * (len(xs) - 1)
    lo = int(math.floor(pos))
    hi = min(lo + 1, len(xs) - 1)
    frac = pos - lo
    return xs[lo] * (1.0 - frac) + xs[hi] * frac


def _round(x: Optional[float], digits: int = 9) -> Optional[float]:
    # Fixed rounding keeps artifact bytes stable against float noise from
    # e.g. different summation orders in future parallel scoring.
    return None if x is None else round(float(x), digits)


def scenario_record(r: ScenarioResult) -> dict:
    s = r.spec
    return {
        "name": s.name,
        "family": s.family,
        "algo": r.algo,
        "p": s.p,
        "k": s.k,
        "n": s.n,
        "gpus_per_server": s.gpus_per_server,
        "nvlink_mult": s.nvlink_mult,
        "num_flows": r.num_flows,
        "stragglers": list(s.stragglers),
        "ells": [_round(s.slowdown[i]) for i in s.stragglers],
        "t0": _round(r.t0),
        "lower_bound": _round(r.lower_bound),
        "t_optcc": _round(r.t_optcc),
        "t_ring": _round(r.t_ring),
        "t_predicted": _round(r.t_predicted),
        "overhead_optcc": _round(r.overhead_optcc),
        "overhead_ring": _round(r.overhead_ring),
        "overhead_lb": _round(r.overhead_lb),
        "optcc_vs_lb": _round(r.optcc_vs_lb),
        "gen_ms": _round(r.gen_seconds * 1e3, 6),
        "sim_ms": _round(r.sim_seconds * 1e3, 6),
    }


def _summarize(records: Sequence[dict]) -> dict:
    ov = [r["overhead_optcc"] for r in records]
    vs = [r["optcc_vs_lb"] for r in records]
    gen = [r["gen_ms"] for r in records]
    return {
        "count": len(records),
        "overhead_optcc_p50": _round(percentile(ov, 50)),
        "overhead_optcc_p99": _round(percentile(ov, 99)),
        "overhead_optcc_max": _round(max(ov)),
        "optcc_vs_lb_p50": _round(percentile(vs, 50)),
        "optcc_vs_lb_p99": _round(percentile(vs, 99)),
        "optcc_vs_lb_max": _round(max(vs)),
        "gen_ms_p50": _round(percentile(gen, 50), 6),
        "gen_ms_p99": _round(percentile(gen, 99), 6),
    }


def build_artifact(results: Sequence[ScenarioResult], profile: str,
                   seed: int, deterministic: bool,
                   schedgen_latency_ms: Optional[float] = None) -> dict:
    records = [scenario_record(r) for r in results]
    families = sorted({r["family"] for r in records})
    return {
        "schema": SCHEMA,
        "profile": profile,
        "seed": seed,
        "deterministic": deterministic,
        # Best-of-N descriptor-path re-planning latency at p=1024 (Section
        # 4.3's < 1 ms claim); None on deterministic runs, where wall-clock
        # measurements are excluded so artifacts stay byte-identical.
        "schedgen_latency_ms": _round(schedgen_latency_ms, 6),
        "scenario_count": len(records),
        "summary": {
            "overall": _summarize(records),
            "by_family": {
                fam: _summarize([r for r in records if r["family"] == fam])
                for fam in families
            },
        },
        "scenarios": records,
    }


def canonical_bytes(artifact: dict) -> bytes:
    return json.dumps(artifact, sort_keys=True, separators=(",", ":"),
                      allow_nan=False).encode() + b"\n"


def write_artifact(artifact: dict, path: str) -> None:
    with open(path, "wb") as f:
        f.write(canonical_bytes(artifact))


def _reject_constant(name: str) -> float:
    raise ValueError(f"non-finite JSON constant {name!r} in artifact")


def load_artifact(path: str) -> dict:
    # NaN/Infinity would sail through every comparison in validation and
    # threshold gating (NaN > limit is False), turning the CI gate green on
    # corrupted data - reject them at parse time.
    with open(path, "rb") as f:
        return json.load(f, parse_constant=_reject_constant)


# ----------------------------------------------------------------------------
# validation + regression gating
# ----------------------------------------------------------------------------

def validate_artifact(artifact: dict) -> list[str]:
    """Structural schema check; returns a list of problems (empty = valid)."""
    errs: list[str] = []
    if artifact.get("schema") != SCHEMA:
        errs.append(f"schema is {artifact.get('schema')!r}, want {SCHEMA!r}")
        return errs
    for key in ("profile", "seed", "scenario_count", "summary", "scenarios"):
        if key not in artifact:
            errs.append(f"missing top-level key {key!r}")
    if errs:
        return errs
    scenarios = artifact["scenarios"]
    if artifact["scenario_count"] != len(scenarios):
        errs.append(f"scenario_count {artifact['scenario_count']} != "
                    f"len(scenarios) {len(scenarios)}")
    names = set()
    for i, rec in enumerate(scenarios):
        rec_errs: list[str] = []
        for key, typ in _SCENARIO_REQUIRED.items():
            if key not in rec:
                rec_errs.append(f"scenario[{i}] missing {key!r}")
            elif typ is float:
                if not isinstance(rec[key], (int, float)):
                    rec_errs.append(f"scenario[{i}].{key} not numeric")
            elif not isinstance(rec[key], typ):
                rec_errs.append(f"scenario[{i}].{key} not {typ.__name__}")
        if rec_errs:
            errs.extend(rec_errs)
            continue
        if rec["name"] in names:
            errs.append(f"duplicate scenario name {rec['name']!r}")
        names.add(rec["name"])
        if rec["t_optcc"] < rec["lower_bound"] * (1 - 1e-9):
            errs.append(f"{rec['name']}: t_optcc beats the lower bound")
        if rec["overhead_lb"] > rec["overhead_optcc"] * (1 + 1e-9):
            errs.append(f"{rec['name']}: overhead_lb > overhead_optcc")
    summary = artifact["summary"]
    for group, stats in [("overall", summary.get("overall", {}))] + \
            sorted(summary.get("by_family", {}).items()):
        for key in _SUMMARY_KEYS:
            if key not in stats:
                errs.append(f"summary[{group}] missing {key!r}")
    return errs


def check_thresholds(artifact: dict, thresholds: dict) -> list[str]:
    """Regression gate: compare the artifact's summary against a checked-in
    thresholds file. Returns failures (empty = pass)."""
    fails: list[str] = []
    if thresholds.get("schema") != THRESHOLDS_SCHEMA:
        fails.append(f"thresholds schema is {thresholds.get('schema')!r}, "
                     f"want {THRESHOLDS_SCHEMA!r}")
        return fails
    overall = artifact["summary"]["overall"]
    checks = [
        ("overhead_optcc_p99", "p99 OptCC overhead vs fault-free T0"),
        ("overhead_optcc_max", "max OptCC overhead vs fault-free T0"),
        ("optcc_vs_lb_p99", "p99 OptCC time vs information-theoretic bound"),
        ("optcc_vs_lb_max", "max OptCC time vs information-theoretic bound"),
    ]
    for key, label in checks:
        limit = thresholds.get(f"{key}_max")
        if limit is None:
            continue
        got = overall[key]
        if got > limit:
            fails.append(f"{label}: {got:.6g} > limit {limit:.6g} ({key})")
    min_scen = thresholds.get("min_scenarios")
    if min_scen is not None and artifact["scenario_count"] < min_scen:
        fails.append(f"scenario_count {artifact['scenario_count']} < "
                     f"required {min_scen}")
    lat_limit = thresholds.get("schedgen_latency_ms_max")
    if lat_limit is not None:
        lat = artifact.get("schedgen_latency_ms")
        # None = deterministic run (latency deliberately unmeasured); the
        # gate only fires on measured values.
        if lat is not None and lat > lat_limit:
            fails.append(f"schedule-generation latency at p=1024: "
                         f"{lat:.6g} ms > limit {lat_limit:.6g} ms "
                         f"(schedgen_latency_ms)")
    return fails
