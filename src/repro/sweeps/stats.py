"""Shared summary statistics for artifacts, benchmarks and telemetry.

One home for the pure-Python percentile math that used to be re-derived per
consumer: the sweep artifact (`repro.sweeps.artifact`), the benchmark CSV
front-ends (`benchmarks/`), and the per-stage telemetry summaries
(`repro.obs`). Pure Python so artifact bytes never depend on the numpy
version.
"""
from __future__ import annotations

import math
from typing import Optional, Sequence


def percentile(values: Sequence[float], q: float) -> float:
    """Linear-interpolation percentile (numpy 'linear'), pure Python so the
    artifact bytes don't depend on the numpy version."""
    if not values:
        return math.nan
    xs = sorted(values)
    if len(xs) == 1:
        return xs[0]
    pos = (q / 100.0) * (len(xs) - 1)
    lo = int(math.floor(pos))
    hi = min(lo + 1, len(xs) - 1)
    frac = pos - lo
    return xs[lo] * (1.0 - frac) + xs[hi] * frac


def percentile_or_none(values: Sequence[Optional[float]],
                       q: float) -> Optional[float]:
    """Percentile over the non-None entries; None when nothing is measured
    (deterministic artifacts null out wall-clock fields entirely)."""
    xs = [v for v in values if v is not None]
    if not xs:
        return None
    return percentile(xs, q)


def summarize(values: Sequence[float],
              qs: Sequence[float] = (50.0, 99.0)) -> dict[str, float]:
    """{'p50': ..., 'p99': ..., 'max': ...} for a sample; percentile keys
    follow the requested qs (integral qs render as pNN)."""
    out: dict[str, float] = {}
    for q in qs:
        tag = f"p{q:g}".replace(".", "_")
        out[tag] = percentile(values, q)
    out["max"] = max(values) if values else math.nan
    return out
