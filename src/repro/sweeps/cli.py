"""`python -m repro.sweeps`: run fault-scenario sweeps, write/check artifacts.

Usage:
  python -m repro.sweeps --smoke                      # CI-sized, seconds
  python -m repro.sweeps --full --workers 8           # nightly-sized
  python -m repro.sweeps --smoke --deterministic      # byte-stable artifact
  python -m repro.sweeps --smoke --telemetry          # + per-stage breakdowns
  python -m repro.sweeps --trace smoke_p8_single_e1_75 --trace-out trace.json
  python -m repro.sweeps --trace worst --trace-from BENCH_sweep.json
  python -m repro.sweeps check BENCH_sweep.json --thresholds ci/sweep_thresholds.json
  python -m repro.sweeps summary BENCH_sweep.json --out "$GITHUB_STEP_SUMMARY"
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

from repro.sweeps import artifact as art
from repro.sweeps.engine import grid_for, run_sweep, sanity_check


def _add_run_args(ap: argparse.ArgumentParser) -> None:
    prof = ap.add_mutually_exclusive_group()
    prof.add_argument("--smoke", dest="profile", action="store_const",
                      const="smoke", help="CI-sized grid (seconds on CPU)")
    prof.add_argument("--full", dest="profile", action="store_const",
                      const="full", help="nightly-sized grid (minutes)")
    prof.add_argument("--profile", dest="profile",
                      help="explicit grid name (smoke|full)")
    ap.set_defaults(profile="smoke")
    ap.add_argument("--seed", type=int, default=0,
                    help="seed for the randomized tail of the grid")
    ap.add_argument("--workers", type=int,
                    default=min(os.cpu_count() or 1, 8),
                    help="worker processes (0 = serial)")
    ap.add_argument("--out", default="BENCH_sweep.json",
                    help="artifact path")
    ap.add_argument("--deterministic", action="store_true",
                    help="zero wall-clock fields so the artifact is a pure "
                         "function of the grid (byte-identical across runs)")
    ap.add_argument("--thresholds", default=None,
                    help="optionally gate the fresh artifact against a "
                         "thresholds JSON after the run")
    ap.add_argument("--telemetry", action="store_true",
                    help="attribute each scenario's simulated time to OptCC "
                         "stages along the critical path (adds "
                         "stage_breakdown + per-stage summaries to the "
                         "artifact; timings are bit-identical either way)")
    ap.add_argument("--trace", metavar="SCENARIO", default=None,
                    help="instead of sweeping, simulate one named scenario "
                         "with telemetry and write a Chrome trace "
                         "(chrome://tracing / Perfetto). 'worst' picks the "
                         "highest-overhead scenario from --trace-from")
    ap.add_argument("--trace-out", default="trace.json",
                    help="Chrome-trace output path (with --trace)")
    ap.add_argument("--trace-from", metavar="ARTIFACT", default=None,
                    help="artifact to resolve --trace worst against")


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(prog="python -m repro.sweeps",
                                 description=__doc__.splitlines()[0])
    sub = ap.add_subparsers(dest="cmd")
    _add_run_args(ap)
    chk = sub.add_parser("check", help="validate + threshold-gate an "
                                       "existing artifact")
    chk.add_argument("artifact", help="path to BENCH_sweep.json")
    # SUPPRESS: don't let this subparser's default clobber a --thresholds
    # given before the `check` word (argparse parent/subparser collision).
    chk.add_argument("--thresholds", default=argparse.SUPPRESS,
                     help="thresholds JSON to gate against")
    summ = sub.add_parser("summary", help="render an artifact's summary as "
                                          "a Markdown table (for "
                                          "$GITHUB_STEP_SUMMARY)")
    summ.add_argument("artifact", help="path to BENCH_sweep.json")
    summ.add_argument("--out", default="-",
                      help="write/append the Markdown here ('-' = stdout; "
                           "an existing file is appended to, matching "
                           "$GITHUB_STEP_SUMMARY semantics)")
    return ap


def _gate(artifact_obj: dict, thresholds_path: str | None) -> int:
    errs = art.validate_artifact(artifact_obj)
    for e in errs:
        print(f"SCHEMA FAIL: {e}", file=sys.stderr)
    if errs:
        return 1
    print(f"schema OK: {artifact_obj['scenario_count']} scenarios "
          f"({artifact_obj['schema']})")
    if thresholds_path is None:
        return 0
    with open(thresholds_path) as f:
        thresholds = json.load(f)
    fails = art.check_thresholds(artifact_obj, thresholds)
    for msg in fails:
        print(f"REGRESSION: {msg}", file=sys.stderr)
    if fails:
        return 1
    print(f"thresholds OK ({thresholds_path})")
    return 0


def measure_schedgen_latency(p: int = 1024, k: int = 4,
                             trials: int = 7) -> float:
    """Worst best-of-N wall time (ms) of the descriptor-only re-planning
    path at the paper's p=1024 scale - the '< 1 ms' claim of Section 4.3,
    gated by schedgen_latency_ms_max in the thresholds file. Best-of (not
    mean) per algorithm because the claim is about the algorithm, not
    scheduler noise; worst-of across every registered algorithm the probe
    profiles support (auto/ring/optcc plus each topology's closed-form time
    model and per-topology bound - hierarchical via a multi-GPU profile) so
    the single gate value bounds re-planning latency whichever algorithm
    the runtime asks for."""
    from repro.core import registry
    from repro.core.model import BandwidthProfile
    from repro.core.planner import make_plan
    n = (p - 1) * k * 16
    profiles = [BandwidthProfile.single_straggler(p, 1.5),
                BandwidthProfile.single_straggler(p, 1.5, g=8)]
    worst = 0.0
    for prof in profiles:
        for algo in ("auto",) + registry.supported(prof):
            best = float("inf")
            for _ in range(trials):
                t0 = time.perf_counter()
                make_plan(prof, n=n, k=k, materialize=False, algo=algo)
                best = min(best, time.perf_counter() - t0)
            worst = max(worst, best)
    return worst * 1e3


def worst_scenario_name(artifact_obj: dict) -> str:
    """Name of the scenario with the highest OptCC overhead - the one worth
    staring at in a trace viewer."""
    recs = artifact_obj["scenarios"]
    if not recs:
        raise ValueError("artifact has no scenarios")
    return max(recs, key=lambda r: (r["overhead_optcc"], r["name"]))["name"]


def cmd_trace(args: argparse.Namespace) -> int:
    """Simulate one scenario with telemetry and write a Chrome trace."""
    from repro import obs
    from repro.core.planner import make_plan
    from repro.core.simulator import simulate
    name = args.trace
    if name == "worst":
        if args.trace_from is None:
            print("error: --trace worst needs --trace-from ARTIFACT",
                  file=sys.stderr)
            return 2
        name = worst_scenario_name(art.load_artifact(args.trace_from))
        print(f"worst-overhead scenario: {name}", file=sys.stderr)
    specs = [s for s in grid_for(args.profile, seed=args.seed)
             if s.name == name]
    if not specs:
        print(f"error: scenario {name!r} not in the "
              f"{args.profile!r} grid", file=sys.stderr)
        return 2
    spec = specs[0]
    plan = make_plan(spec.profile(), spec.n, k=spec.k,
                     fill_bubbles=spec.fill_bubbles, materialize="arrays")
    res = simulate(plan.schedule, telemetry=True)
    obs.write_chrome_trace(res.telemetry, args.trace_out, name=spec.name)
    breakdown = obs.stage_breakdown(res.telemetry)
    print(f"wrote {args.trace_out}: {spec.name} algo={plan.algo} "
          f"T={res.makespan:.6g} ({res.telemetry.nflows} flows)")
    for stage, v in sorted(breakdown.items(), key=lambda kv: -kv[1]):
        print(f"  {stage:10s} {v:14.3f}  ({v / res.makespan:6.1%})")
    return 0


def _fmt_ms(x) -> str:
    return "-" if x is None else f"{x:.3f}ms"


def cmd_run(args: argparse.Namespace) -> int:
    t_start = time.perf_counter()
    specs = grid_for(args.profile, seed=args.seed)
    print(f"sweep profile={args.profile} seed={args.seed}: "
          f"{len(specs)} scenarios, workers={args.workers}"
          f"{' +telemetry' if args.telemetry else ''}", file=sys.stderr)
    run_stats: dict = {}
    results = run_sweep(specs, workers=args.workers,
                        measure_latency=not args.deterministic,
                        telemetry=args.telemetry, stats=run_stats)
    if run_stats.get("retries"):
        print(f"worker fan-out: {run_stats['retries']} chunk retr"
              f"{'y' if run_stats['retries'] == 1 else 'ies'} after "
              f"crash/hang", file=sys.stderr)
    bad = sanity_check(results)
    for msg in bad:
        print(f"INVARIANT FAIL: {msg}", file=sys.stderr)
    schedgen_ms = None if args.deterministic else measure_schedgen_latency()
    artifact_obj = art.build_artifact(results, profile=args.profile,
                                      seed=args.seed,
                                      deterministic=args.deterministic,
                                      schedgen_latency_ms=schedgen_ms,
                                      telemetry=args.telemetry,
                                      retries=run_stats.get("retries", 0))
    art.write_artifact(artifact_obj, args.out)
    wall = time.perf_counter() - t_start
    overall = artifact_obj["summary"]["overall"]
    print(f"wrote {args.out}: {len(results)} scenarios in {wall:.1f}s | "
          f"overhead p50={overall['overhead_optcc_p50']:.4f} "
          f"p99={overall['overhead_optcc_p99']:.4f} "
          f"max={overall['overhead_optcc_max']:.4f} | "
          f"vs-LB p99={overall['optcc_vs_lb_p99']:.4f} | "
          f"gen p99={_fmt_ms(overall['gen_ms_p99'])} | "
          f"schedgen(p=1024)={_fmt_ms(schedgen_ms)}")
    if args.telemetry:
        for stage, st in sorted(overall["stages"].items()):
            print(f"  stage {stage:10s} n={st['count']:4d} "
                  f"overhead p50={st['overhead_p50']:.4f} "
                  f"p99={st['overhead_p99']:.4f} "
                  f"max={st['overhead_max']:.4f}")
    if bad:
        return 1
    return _gate(artifact_obj, args.thresholds)


def cmd_check(args: argparse.Namespace) -> int:
    return _gate(art.load_artifact(args.artifact), args.thresholds)


def _md(x, fmt: str = "{:.4f}") -> str:
    return "–" if x is None else fmt.format(x)


def format_markdown_summary(artifact_obj: dict) -> str:
    """Render the artifact's summary block as GitHub-flavored Markdown:
    overall + per-family overhead percentiles (replay families additionally
    show the no-replan baseline's percentiles) and, on telemetry artifacts,
    the per-stage critical-path table."""
    summary = artifact_obj["summary"]
    out = [f"### Sweep summary — `{artifact_obj['profile']}` grid, "
           f"{artifact_obj['scenario_count']} scenarios "
           f"(`{artifact_obj['schema']}`)", ""]
    out.append("| group | count | overhead p50 | overhead p99 | "
               "overhead max | vs-LB p99 | no-replan p99 | vs-oracle p99 | "
               "vs-auto p99 | gen ms p99 |")
    out.append("|---|---|---|---|---|---|---|---|---|---|")
    groups = [("**overall**", summary["overall"])]
    groups += sorted(summary.get("by_family", {}).items())
    # Detection records again, grouped by controller policy - the rows that
    # show what debounce/backoff buy over reacting to every probe.
    groups += [(f"policy:{pol}", st)
               for pol, st in sorted(summary.get("by_policy", {}).items())]
    # Topology records again, grouped by requested algorithm - the per-algo
    # overhead rows (vs its own lower bound, and vs the planner's auto pick).
    groups += [(f"algo:{algo}", st)
               for algo, st in sorted(summary.get("by_algo", {}).items())]
    for name, st in groups:
        out.append(
            f"| {name} | {st['count']} | {_md(st['overhead_optcc_p50'])} | "
            f"{_md(st['overhead_optcc_p99'])} | "
            f"{_md(st['overhead_optcc_max'])} | "
            f"{_md(st['optcc_vs_lb_p99'])} | "
            f"{_md(st.get('overhead_noreplan_p99'))} | "
            f"{_md(st.get('overhead_vs_oracle_p99'))} | "
            f"{_md(st.get('overhead_vs_auto_p99'))} | "
            f"{_md(st['gen_ms_p99'], '{:.3f}')} |")
    stages = summary["overall"].get("stages")
    if stages:
        out += ["", "#### Critical-path stages (overall)", ""]
        out.append("| stage | count | overhead p50 | overhead p99 | "
                   "overhead max |")
        out.append("|---|---|---|---|---|")
        for stage, st in sorted(stages.items()):
            out.append(f"| {stage} | {st['count']} | "
                       f"{_md(st['overhead_p50'])} | "
                       f"{_md(st['overhead_p99'])} | "
                       f"{_md(st['overhead_max'])} |")
    lat = artifact_obj.get("schedgen_latency_ms")
    out += ["", f"schedule-gen latency (p=1024, best-of-N): "
                f"{_md(lat, '{:.3f}')} ms", ""]
    return "\n".join(out)


def cmd_summary(args: argparse.Namespace) -> int:
    md = format_markdown_summary(art.load_artifact(args.artifact))
    if args.out == "-":
        print(md)
    else:
        with open(args.out, "a") as f:
            f.write(md + "\n")
    return 0


def main(argv: list[str] | None = None) -> int:
    args = build_parser().parse_args(argv)
    try:
        if args.cmd == "check":
            return cmd_check(args)
        if args.cmd == "summary":
            return cmd_summary(args)
        if args.trace is not None:
            return cmd_trace(args)
        return cmd_run(args)
    except (ValueError, OSError, json.JSONDecodeError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
