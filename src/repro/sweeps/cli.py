"""`python -m repro.sweeps`: run fault-scenario sweeps, write/check artifacts.

Usage:
  python -m repro.sweeps --smoke                      # CI-sized, seconds
  python -m repro.sweeps --full --workers 8           # nightly-sized
  python -m repro.sweeps --smoke --deterministic      # byte-stable artifact
  python -m repro.sweeps check BENCH_sweep.json --thresholds ci/sweep_thresholds.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

from repro.sweeps import artifact as art
from repro.sweeps.engine import grid_for, run_sweep, sanity_check


def _add_run_args(ap: argparse.ArgumentParser) -> None:
    prof = ap.add_mutually_exclusive_group()
    prof.add_argument("--smoke", dest="profile", action="store_const",
                      const="smoke", help="CI-sized grid (seconds on CPU)")
    prof.add_argument("--full", dest="profile", action="store_const",
                      const="full", help="nightly-sized grid (minutes)")
    prof.add_argument("--profile", dest="profile",
                      help="explicit grid name (smoke|full)")
    ap.set_defaults(profile="smoke")
    ap.add_argument("--seed", type=int, default=0,
                    help="seed for the randomized tail of the grid")
    ap.add_argument("--workers", type=int,
                    default=min(os.cpu_count() or 1, 8),
                    help="worker processes (0 = serial)")
    ap.add_argument("--out", default="BENCH_sweep.json",
                    help="artifact path")
    ap.add_argument("--deterministic", action="store_true",
                    help="zero wall-clock fields so the artifact is a pure "
                         "function of the grid (byte-identical across runs)")
    ap.add_argument("--thresholds", default=None,
                    help="optionally gate the fresh artifact against a "
                         "thresholds JSON after the run")


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(prog="python -m repro.sweeps",
                                 description=__doc__.splitlines()[0])
    sub = ap.add_subparsers(dest="cmd")
    _add_run_args(ap)
    chk = sub.add_parser("check", help="validate + threshold-gate an "
                                       "existing artifact")
    chk.add_argument("artifact", help="path to BENCH_sweep.json")
    # SUPPRESS: don't let this subparser's default clobber a --thresholds
    # given before the `check` word (argparse parent/subparser collision).
    chk.add_argument("--thresholds", default=argparse.SUPPRESS,
                     help="thresholds JSON to gate against")
    return ap


def _gate(artifact_obj: dict, thresholds_path: str | None) -> int:
    errs = art.validate_artifact(artifact_obj)
    for e in errs:
        print(f"SCHEMA FAIL: {e}", file=sys.stderr)
    if errs:
        return 1
    print(f"schema OK: {artifact_obj['scenario_count']} scenarios "
          f"({artifact_obj['schema']})")
    if thresholds_path is None:
        return 0
    with open(thresholds_path) as f:
        thresholds = json.load(f)
    fails = art.check_thresholds(artifact_obj, thresholds)
    for msg in fails:
        print(f"REGRESSION: {msg}", file=sys.stderr)
    if fails:
        return 1
    print(f"thresholds OK ({thresholds_path})")
    return 0


def measure_schedgen_latency(p: int = 1024, k: int = 4,
                             trials: int = 7) -> float:
    """Best-of-N wall time (ms) of the O(pk) descriptor-only re-planning
    path at the paper's p=1024 scale - the '< 1 ms' claim of Section 4.3,
    gated by schedgen_latency_ms_max in the thresholds file. Best-of (not
    mean) because the claim is about the algorithm, not scheduler noise."""
    from repro.core.model import BandwidthProfile
    from repro.core.planner import make_plan
    prof = BandwidthProfile.single_straggler(p, 1.5)
    n = (p - 1) * k * 16
    best = float("inf")
    for _ in range(trials):
        t0 = time.perf_counter()
        make_plan(prof, n=n, k=k, materialize=False)
        best = min(best, time.perf_counter() - t0)
    return best * 1e3


def cmd_run(args: argparse.Namespace) -> int:
    t_start = time.perf_counter()
    specs = grid_for(args.profile, seed=args.seed)
    print(f"sweep profile={args.profile} seed={args.seed}: "
          f"{len(specs)} scenarios, workers={args.workers}", file=sys.stderr)
    results = run_sweep(specs, workers=args.workers,
                        measure_latency=not args.deterministic)
    bad = sanity_check(results)
    for msg in bad:
        print(f"INVARIANT FAIL: {msg}", file=sys.stderr)
    schedgen_ms = None if args.deterministic else measure_schedgen_latency()
    artifact_obj = art.build_artifact(results, profile=args.profile,
                                      seed=args.seed,
                                      deterministic=args.deterministic,
                                      schedgen_latency_ms=schedgen_ms)
    art.write_artifact(artifact_obj, args.out)
    wall = time.perf_counter() - t_start
    overall = artifact_obj["summary"]["overall"]
    lat = ("-" if schedgen_ms is None else f"{schedgen_ms:.3f}ms")
    print(f"wrote {args.out}: {len(results)} scenarios in {wall:.1f}s | "
          f"overhead p50={overall['overhead_optcc_p50']:.4f} "
          f"p99={overall['overhead_optcc_p99']:.4f} "
          f"max={overall['overhead_optcc_max']:.4f} | "
          f"vs-LB p99={overall['optcc_vs_lb_p99']:.4f} | "
          f"gen p99={overall['gen_ms_p99']:.3f}ms | "
          f"schedgen(p=1024)={lat}")
    if bad:
        return 1
    return _gate(artifact_obj, args.thresholds)


def cmd_check(args: argparse.Namespace) -> int:
    return _gate(art.load_artifact(args.artifact), args.thresholds)


def main(argv: list[str] | None = None) -> int:
    args = build_parser().parse_args(argv)
    try:
        if args.cmd == "check":
            return cmd_check(args)
        return cmd_run(args)
    except (ValueError, OSError, json.JSONDecodeError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
