"""Fault-scenario sweep engine.

Generates large deterministic grids of degradation scenarios (single/multi
straggler, multi-GPU servers, heterogeneous slowdowns, correlated server
faults), runs each through the online planner + bandwidth simulator, scores
against the paper's lower bounds, and emits a versioned JSON perf artifact
(BENCH_sweep.json) that CI gates on. See `python -m repro.sweeps --help`.

Public API:
  ScenarioSpec, smoke_grid, full_grid, GRIDS   - scenario grids
  run_scenario, run_sweep, ScenarioResult      - execution engine
  build_artifact, validate_artifact,
  check_thresholds, write_artifact,
  load_artifact, canonical_bytes               - artifact I/O + gating
"""
from repro.sweeps.artifact import (SCHEMA, THRESHOLDS_SCHEMA, build_artifact,
                                   canonical_bytes, check_thresholds,
                                   load_artifact, validate_artifact,
                                   write_artifact)
from repro.sweeps.engine import (ScenarioResult, grid_for, run_scenario,
                                 run_sweep, sanity_check)
from repro.sweeps.scenarios import (GRIDS, PAPER_ELLS, ScenarioSpec,
                                    full_grid, gen_detection, gen_replay,
                                    load_trace, smoke_grid, traces_dir)
from repro.sweeps.stats import percentile, percentile_or_none, summarize

__all__ = [
    "ScenarioSpec", "ScenarioResult", "GRIDS", "PAPER_ELLS",
    "smoke_grid", "full_grid", "grid_for",
    "gen_detection", "gen_replay", "load_trace", "traces_dir",
    "run_scenario", "run_sweep", "sanity_check",
    "SCHEMA", "THRESHOLDS_SCHEMA",
    "build_artifact", "canonical_bytes", "validate_artifact",
    "check_thresholds", "write_artifact", "load_artifact",
    "percentile", "percentile_or_none", "summarize",
]
