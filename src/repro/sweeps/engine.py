"""Scenario sweep engine: grid -> (plan, simulate, score) -> artifact rows.

Each scenario runs the full online path a production deployment would:
`planner.make_plan` builds the OptCC schedule for the degraded profile
(timed - this is the claimed <1ms re-planning latency), `core.simulate`
executes it in the bandwidth-bound flow model, and the result is scored
against the profile's information-theoretic lower bound and the fault-free
optimum T0. Optionally the unchanged degraded ring (the ICCL baseline) is
simulated on the same profile for a head-to-head overhead comparison.

Scenario execution is embarrassingly parallel; `run_sweep` fans the grid out
over worker processes via core.simulator.map_scenarios (workers=0 -> serial,
same results - the model is deterministic).
"""
from __future__ import annotations

import concurrent.futures
import dataclasses
import functools
import time
from concurrent.futures import ProcessPoolExecutor
from concurrent.futures.process import BrokenProcessPool
from typing import Optional, Sequence

from repro.core.planner import make_plan
from repro.core.schedule_vec import ring_arrays
from repro.core.simulator import simulate
from repro.sweeps.scenarios import GRIDS, ScenarioSpec


@dataclasses.dataclass
class ScenarioResult:
    """Scored outcome of one scenario. Times are element-time units."""

    spec: ScenarioSpec
    algo: str
    t_optcc: float                 # simulated OptCC completion time
    t_ring: Optional[float]        # simulated degraded ring (ICCL), if run
    t_predicted: float             # planner's closed-form prediction
    lower_bound: float             # tightest applicable theorem
    t0: float                      # fault-free optimum (Patarasuk-Yuan)
    num_flows: int
    gen_seconds: float             # schedule-generation wall time
    sim_seconds: float             # OptCC simulation wall time (not a claim)
    ring_sim_seconds: float = 0.0  # ring-baseline simulation wall time
    # Critical-path stage attribution ({stage: element-time}, sums to
    # t_optcc); only populated when the sweep runs with telemetry on.
    stage_breakdown: Optional[dict] = None
    # Replay-family fields (spec.events non-empty). t_optcc then carries the
    # *adopted* makespan - the re-planning controller rides the original
    # plan or splices in fresh ones, whichever is better - so every
    # overhead metric keeps meaning "what the system achieved". t_noreplan
    # is the original plan ridden through the whole timeline (the baseline
    # re-planning is scored against); stage_breakdown attributes *it*, so
    # for replay scenarios the breakdown sums to t_noreplan, not t_optcc.
    t_noreplan: Optional[float] = None
    replans: Optional[int] = None
    # Topology-family fields (spec.algo != "auto"). The scenario plans the
    # *explicitly requested* registry algorithm - t_optcc is its simulated
    # makespan and lower_bound its per-topology bound - and additionally
    # simulates what make_plan(algo="auto") would have run on the very same
    # profile, so overhead_vs_auto prices the topology against the planner's
    # choice (>1: auto was right to avoid it; <1: the time models leave
    # wins on the table).
    requested_algo: Optional[str] = None
    t_auto: Optional[float] = None
    # Detection-family fields (spec.detection non-empty). t_optcc is the
    # *imperfect* controller's adopted makespan; t_oracle the PR-8
    # zero-delay perfect-knowledge controller's on the same timeline, so
    # overhead_vs_oracle prices the detection imperfection itself.
    policy: Optional[str] = None
    t_oracle: Optional[float] = None
    false_replans: Optional[int] = None
    suppressed: Optional[int] = None
    detect_lag_mean: Optional[float] = None
    detect_lag_max: Optional[float] = None
    detect_missed: Optional[int] = None

    @property
    def overhead_optcc(self) -> float:
        """Simulated time vs the fault-free optimum (the paper's metric)."""
        return self.t_optcc / self.t0

    @property
    def overhead_noreplan(self) -> Optional[float]:
        return None if self.t_noreplan is None else self.t_noreplan / self.t0

    @property
    def overhead_ring(self) -> Optional[float]:
        return None if self.t_ring is None else self.t_ring / self.t0

    @property
    def overhead_vs_oracle(self) -> Optional[float]:
        """Price of imperfect detection: imperfect controller's adopted
        makespan vs the zero-delay perfect-knowledge controller's."""
        return None if self.t_oracle is None else self.t_optcc / self.t_oracle

    @property
    def overhead_vs_auto(self) -> Optional[float]:
        """Requested topology vs the planner's auto pick, same profile."""
        return None if self.t_auto is None else self.t_optcc / self.t_auto

    @property
    def overhead_lb(self) -> float:
        """Unavoidable overhead: no algorithm can beat this."""
        return self.lower_bound / self.t0

    @property
    def optcc_vs_lb(self) -> float:
        """Schedule quality: simulated time vs the lower bound (>= 1 always,
        or the simulator/bound is broken)."""
        return self.t_optcc / self.lower_bound


def run_scenario(spec: ScenarioSpec,
                 measure_latency: bool = True,
                 telemetry: bool = False) -> ScenarioResult:
    """Plan + simulate + score one scenario.

    telemetry=True additionally attributes the simulated makespan to OptCC
    stages along the critical path (`repro.obs`). Attribution is derived
    *after* the timed simulation from its recorded flow times, so t_optcc is
    bit-identical with and without it.

    Specs with a failure timeline (`spec.events`, the replay family) run the
    time-varying path instead: t_optcc is the makespan the mid-flight
    re-planning controller achieves, and the original plan ridden through
    the whole timeline lands in t_noreplan.

    Specs naming an explicit algorithm (`spec.algo != "auto"`, the topology
    family) plan that registry entry instead of letting the planner choose,
    and score it against both its per-topology lower bound and the auto
    pick on the same profile (`t_auto` / overhead_vs_auto).
    """
    if spec.events:
        return _run_replay_scenario(spec, measure_latency=measure_latency,
                                    telemetry=telemetry)
    if spec.algo != "auto":
        return _run_topology_scenario(spec, measure_latency=measure_latency,
                                      telemetry=telemetry)
    profile = spec.profile()
    plan = make_plan(profile, spec.n, k=spec.k,
                     fill_bubbles=spec.fill_bubbles, materialize="arrays")
    t_sim0 = time.perf_counter()
    res = simulate(plan.schedule)
    t_optcc = res.makespan
    sim_seconds = time.perf_counter() - t_sim0
    stage_breakdown = None
    if telemetry:
        from repro import obs
        stage_breakdown = obs.stage_breakdown(obs.collect(plan.schedule, res))
    t_ring = None
    ring_sim_seconds = 0.0
    if spec.simulate_ring:
        if plan.schedule.meta.get("algo") == "ring":
            t_ring = t_optcc          # healthy: the plan already is the ring
        else:
            t_ring0 = time.perf_counter()
            t_ring = simulate(ring_arrays(profile, spec.n)).makespan
            ring_sim_seconds = time.perf_counter() - t_ring0
    return ScenarioResult(
        spec=spec,
        algo=plan.algo,
        t_optcc=t_optcc,
        t_ring=t_ring,
        t_predicted=plan.predicted_time,
        lower_bound=plan.lower_bound,
        t0=plan.t0,
        num_flows=plan.schedule.num_flows,
        gen_seconds=plan.gen_seconds if measure_latency else 0.0,
        sim_seconds=sim_seconds if measure_latency else 0.0,
        ring_sim_seconds=ring_sim_seconds if measure_latency else 0.0,
        stage_breakdown=stage_breakdown,
    )


def _run_topology_scenario(spec: ScenarioSpec,
                           measure_latency: bool = True,
                           telemetry: bool = False) -> ScenarioResult:
    """Topology-family scenario: plan the explicitly requested registry
    algorithm (hierarchical / dbtree / torus2d / ...), simulate it, and
    score it twice - against its *own* per-topology lower bound (the
    optcc_vs_lb column, gated per-family in CI) and against the makespan
    `make_plan(algo="auto")` achieves on the identical profile (t_auto, so
    overhead_vs_auto says what explicitly requesting this topology costs or
    saves vs trusting the planner)."""
    profile = spec.profile()
    plan = make_plan(profile, spec.n, k=spec.k,
                     fill_bubbles=spec.fill_bubbles, materialize=True,
                     algo=spec.algo)
    t_sim0 = time.perf_counter()
    res = simulate(plan.schedule)
    t_topo = res.makespan
    sim_seconds = time.perf_counter() - t_sim0
    stage_breakdown = None
    if telemetry:
        from repro import obs
        stage_breakdown = obs.stage_breakdown(obs.collect(plan.schedule, res))
    auto_plan = make_plan(profile, spec.n, k=spec.k,
                          fill_bubbles=spec.fill_bubbles,
                          materialize="arrays")
    t_auto = simulate(auto_plan.schedule).makespan
    return ScenarioResult(
        spec=spec,
        algo=plan.algo,
        t_optcc=t_topo,
        t_ring=None,
        t_predicted=plan.predicted_time,
        lower_bound=plan.lower_bound,
        t0=plan.t0,
        num_flows=plan.schedule.num_flows,
        gen_seconds=plan.gen_seconds if measure_latency else 0.0,
        sim_seconds=sim_seconds if measure_latency else 0.0,
        stage_breakdown=stage_breakdown,
        requested_algo=spec.algo,
        t_auto=t_auto,
    )


def _run_replay_scenario(spec: ScenarioSpec,
                         measure_latency: bool = True,
                         telemetry: bool = False) -> ScenarioResult:
    """Replay-family scenario: one collective under a failure timeline,
    scored with and without mid-flight re-planning.

    The spec's event times are in units of the scenario's fault-free optimum
    T0, so the same trace shape is meaningful at every (p, n, k); they are
    rescaled to element-time here. t_optcc carries the controller's adopted
    makespan (so every overhead metric scores the system's actual behavior),
    t_noreplan the original plan ridden through the whole timeline, and the
    lower bound is the timeline bound (static bound of the per-rank
    best-ever rates).
    """
    from repro.core import lower_bounds as lb
    from repro.core.model import FaultTimeline
    from repro.core.planner import replay

    profile = spec.profile()
    scale = lb.t0_fault_free(spec.p, spec.n, spec.gpus_per_server)
    tl = FaultTimeline.make([(t * scale, r, l) for t, r, l in spec.events])

    detector = controller = None
    if spec.detection:
        from repro.detect import ControllerConfig, DetectorConfig
        params = dict(spec.detection)
        policy = str(params.pop("policy", "immediate"))
        # Detection time parameters are specified in T0 units like the
        # trace events; rescale them to element-time alongside.
        detector = DetectorConfig(
            probe_interval=float(params.get("probe_interval", 0.0)) * scale,
            latency=float(params.get("latency", 0.0)) * scale,
            noise=float(params.get("noise", 0.0)),
            quant=float(params.get("quant", 0.0)),
            fp_rate=float(params.get("fp_rate", 0.0)),
            fn_rate=float(params.get("fn_rate", 0.0)),
            seed=int(params.get("seed", 0)),
        )
        controller = ControllerConfig(
            policy=policy,
            debounce_probes=int(params.get("debounce_probes", 3)),
            backoff_base=float(params.get("backoff_base", 0.0)) * scale,
        )

    t_sim0 = time.perf_counter()
    rr = replay(profile, spec.n, tl, k=spec.k,
                fill_bubbles=spec.fill_bubbles,
                detector=detector, controller=controller)
    sim_seconds = time.perf_counter() - t_sim0
    t_oracle = None
    if spec.detection:
        # Score the imperfect controller against the PR-8 zero-delay
        # perfect-knowledge chain on the very same true timeline.
        rr_oracle = replay(profile, spec.n, tl, k=spec.k,
                           fill_bubbles=spec.fill_bubbles)
        t_oracle = rr_oracle.t_replan
    plan0 = rr.plan0
    stage_breakdown = None
    if telemetry:
        from repro import obs
        stage_breakdown = obs.stage_breakdown(
            obs.collect(plan0.schedule, rr.noreplan_result))
    det = rr.detection
    return ScenarioResult(
        spec=spec,
        algo=plan0.algo,
        t_optcc=rr.t_replan,
        t_ring=None,
        t_predicted=plan0.predicted_time,
        lower_bound=rr.lower_bound,
        t0=rr.t0,
        num_flows=plan0.schedule.num_flows,
        gen_seconds=plan0.gen_seconds if measure_latency else 0.0,
        sim_seconds=sim_seconds if measure_latency else 0.0,
        stage_breakdown=stage_breakdown,
        t_noreplan=rr.t_noreplan,
        replans=rr.replans,
        policy=rr.policy if spec.detection else None,
        t_oracle=t_oracle,
        false_replans=rr.false_replans if spec.detection else None,
        suppressed=rr.suppressed if spec.detection else None,
        detect_lag_mean=rr.detect_lag_mean if spec.detection else None,
        detect_lag_max=rr.detect_lag_max if spec.detection else None,
        detect_missed=det.missed if det is not None else None,
    )


def _run_chunk(fn, chunk: list[ScenarioSpec]) -> list[ScenarioResult]:
    """Worker-side unit of the fan-out: one chunk of specs, in order.
    Module-level so it pickles into the process pool."""
    return [fn(spec) for spec in chunk]


def run_sweep(specs: Sequence[ScenarioSpec], workers: int = 0,
              measure_latency: bool = True,
              telemetry: bool = False,
              stats: Optional[dict] = None,
              chunk_timeout: float = 300.0,
              max_retries: int = 2) -> list[ScenarioResult]:
    """Run a scenario grid, preserving grid order.

    measure_latency=False zeroes all wall-clock fields, making the results -
    and the artifact built from them - a pure function of the grid
    (byte-identical across runs; the determinism CI check uses this).
    telemetry=True populates each result's stage_breakdown (deterministic
    too: attribution is pure arithmetic on simulated times).

    The parallel fan-out is crash/hang-hardened: the grid is split into
    chunks, and a chunk whose worker dies (BrokenProcessPool / OSError) or
    hangs past `chunk_timeout` seconds is re-submitted to a fresh pool up to
    `max_retries` times with exponential backoff; whatever still fails after
    that runs serially in-process (scenarios are pure functions of their
    specs, so re-running is always safe and bit-identical). Pass a `stats`
    dict to receive {"retries": <chunk re-submissions>} - the sweep CLI
    records it in the artifact. Deterministic errors raised by a scenario
    itself (e.g. an invalid spec) are not retried; they propagate.
    """
    # partial of a module-level function pickles, so the process pool works.
    fn = functools.partial(run_scenario, measure_latency=measure_latency,
                           telemetry=telemetry)
    if stats is None:
        stats = {}
    stats.setdefault("retries", 0)
    specs = list(specs)
    if workers <= 0 or len(specs) <= 1:
        return [fn(s) for s in specs]

    csize = max(1, len(specs) // (8 * workers))
    pending = [(i, specs[i:i + csize]) for i in range(0, len(specs), csize)]
    results: list[Optional[ScenarioResult]] = [None] * len(specs)

    for attempt in range(max_retries + 1):
        if not pending:
            break
        if attempt:
            stats["retries"] += len(pending)
            time.sleep(0.25 * (2 ** (attempt - 1)))
        try:
            pool = ProcessPoolExecutor(max_workers=workers)
        except OSError:
            break                      # cannot pool at all -> serial below
        failed: list[tuple[int, list[ScenarioSpec]]] = []
        futs = {pool.submit(_run_chunk, fn, chunk): (start, chunk)
                for start, chunk in pending}
        try:
            while futs:
                # Hang detection is progress-based: the round only aborts
                # when *no* chunk completes for chunk_timeout seconds, so a
                # long grid that is still making progress never false-fires.
                done, _ = concurrent.futures.wait(
                    futs, timeout=chunk_timeout,
                    return_when=concurrent.futures.FIRST_COMPLETED)
                if not done:
                    failed.extend(futs.values())  # hung (or queued behind one)
                    futs.clear()
                    break
                for fut in done:
                    start, chunk = futs.pop(fut)
                    try:
                        results[start:start + len(chunk)] = fut.result()
                    except (OSError, BrokenProcessPool):
                        failed.append((start, chunk))
        finally:
            pool.shutdown(wait=False, cancel_futures=True)
        pending = sorted(failed)

    for start, chunk in sorted(pending):   # last resort: serial, in-process
        results[start:start + len(chunk)] = [fn(s) for s in chunk]
    return results


def grid_for(profile: str, seed: int = 0) -> list[ScenarioSpec]:
    try:
        return GRIDS[profile](seed)
    except KeyError:
        raise ValueError(f"unknown sweep profile {profile!r}; "
                         f"choose from {sorted(GRIDS)}") from None


def sanity_check(results: Sequence[ScenarioResult],
                 tol: float = 1e-9) -> list[str]:
    """Model-level invariant violations (empty list = all good):
    simulated time must dominate the information-theoretic lower bound."""
    bad = []
    for r in results:
        if r.t_optcc < r.lower_bound * (1.0 - tol):
            bad.append(f"{r.spec.name}: simulated {r.t_optcc:.6g} < "
                       f"lower bound {r.lower_bound:.6g}")
        if r.t_noreplan is not None and r.t_optcc > r.t_noreplan * (1.0 + tol):
            bad.append(f"{r.spec.name}: replanned {r.t_optcc:.6g} > "
                       f"no-replan {r.t_noreplan:.6g} (controller must "
                       f"adopt the better schedule)")
    return bad
