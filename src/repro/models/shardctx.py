"""Activation-sharding context for GSPMD scan bodies.

Sharding does not reliably propagate into lax.scan carries (the layer
stack), so without in-body constraints XLA may replicate the token
dimension inside every layer - silently multiplying compute and memory by
the data-parallel degree. The launch layer sets the batch axes here before
building the program; model code calls constrain_batch on its scan
carries. Outside a mesh context this is a no-op (single-device tests).
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import PartitionSpec as P

_BATCH_AXES: Optional[tuple] = None


def set_batch_axes(axes: Optional[tuple]) -> None:
    global _BATCH_AXES
    _BATCH_AXES = tuple(axes) if axes else None


def get_batch_axes() -> Optional[tuple]:
    return _BATCH_AXES


def constrain_batch(x: jax.Array, extra_dims: Optional[int] = None):
    """Constrain x's leading (batch) dim to the configured axes."""
    if _BATCH_AXES is None:
        return x
    nd = (x.ndim - 1) if extra_dims is None else extra_dims
    axes = _BATCH_AXES if len(_BATCH_AXES) > 1 else _BATCH_AXES[0]
    try:
        return jax.lax.with_sharding_constraint(x, P(axes, *([None] * nd)))
    except Exception:
        return x


def constrain(x: jax.Array, spec: P):
    if _BATCH_AXES is None:
        return x
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x
