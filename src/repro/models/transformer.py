"""Dense decoder-only transformer: qwen3 / minicpm / internlm2 / gemma3 /
qwen2-vl backbone. Layers are stacked and scanned (compile-time O(1) in
depth); gemma3's 5:1 local:global attention is a per-layer boolean routed
through the scan; decode uses full KV caches for global layers and rolling
window caches for local layers.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models.common import (apply_mrope, apply_rope,
                                 chunked_softmax_xent, embed_tokens,
                                 init_dense, rms_norm, swiglu)
from repro.models.shardctx import constrain_batch


def _pdt(cfg):
    return jnp.dtype(cfg.param_dtype)


def _cdt(cfg):
    return jnp.dtype(cfg.compute_dtype)


def layer_is_global(cfg: ModelConfig) -> np.ndarray:
    if cfg.global_every <= 0:
        return np.ones(cfg.n_layers, bool)
    return np.array([(l + 1) % cfg.global_every == 0
                     for l in range(cfg.n_layers)])


# ----------------------------------------------------------------------------
# parameters
# ----------------------------------------------------------------------------

def init_block_params(cfg: ModelConfig, key, n_layers: int,
                      cross_attn: bool = False) -> dict:
    hd, H, KV = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 12)
    L = n_layers
    dt = _pdt(cfg)

    def W(i, shape):
        return init_dense(ks[i], (L,) + shape, dtype=dt)

    params = {
        "ln1": jnp.zeros((L, d), dt),
        "wq": W(0, (d, H * hd)),
        "wk": W(1, (d, KV * hd)),
        "wv": W(2, (d, KV * hd)),
        "wo": W(3, (H * hd, d)),
        "ln2": jnp.zeros((L, d), dt),
        "w_gate": W(4, (d, f)),
        "w_up": W(5, (d, f)),
        "w_down": W(6, (f, d)),
    }
    if cfg.qk_norm:
        params["q_norm"] = jnp.zeros((L, hd), dt)
        params["k_norm"] = jnp.zeros((L, hd), dt)
    if cross_attn:
        params["ln_x"] = jnp.zeros((L, d), dt)
        params["xq"] = W(7, (d, H * hd))
        params["xk"] = W(8, (d, KV * hd))
        params["xv"] = W(9, (d, KV * hd))
        params["xo"] = W(10, (H * hd, d))
    return params


def init_params(cfg: ModelConfig, key) -> dict:
    k_emb, k_blocks, k_head, k_moe = jax.random.split(key, 4)
    dt = _pdt(cfg)
    blocks = init_block_params(cfg, k_blocks, cfg.n_layers)
    if cfg.n_experts > 0:
        from repro.models.moe import init_moe_params
        for name in ("w_gate", "w_up", "w_down"):
            del blocks[name]
        blocks.update(init_moe_params(cfg, k_moe, cfg.n_layers))
    params = {
        "embed": init_dense(k_emb, (cfg.vocab_size, cfg.d_model),
                            scale=0.02, dtype=dt),
        "blocks": blocks,
        "final_norm": jnp.zeros((cfg.d_model,), dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = init_dense(k_head,
                                       (cfg.d_model, cfg.vocab_size),
                                       scale=0.02, dtype=dt)
    return params


def ffn_apply(cfg: ModelConfig, h: jax.Array, bp: dict) -> jax.Array:
    """Dense SwiGLU or MoE FFN, keyed on the config."""
    if cfg.n_experts > 0:
        from repro.models.moe import moe_ffn
        return moe_ffn(cfg, h, bp)
    return swiglu(h, bp["w_gate"], bp["w_up"], bp["w_down"])


def unembed_matrix(cfg: ModelConfig, params) -> jax.Array:
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["lm_head"]


# ----------------------------------------------------------------------------
# forward (training / prefill)
# ----------------------------------------------------------------------------

def _project_qkv(cfg, bp, x, positions):
    B, S, d = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = jnp.einsum("bsd,dh->bsh", x, bp["wq"]).reshape(B, S, H, hd)
    k = jnp.einsum("bsd,dh->bsh", x, bp["wk"]).reshape(B, S, KV, hd)
    v = jnp.einsum("bsd,dh->bsh", x, bp["wv"]).reshape(B, S, KV, hd)
    if cfg.qk_norm:
        q = rms_norm(q, bp["q_norm"], cfg.norm_eps)
        k = rms_norm(k, bp["k_norm"], cfg.norm_eps)
    if cfg.mrope:
        q = apply_mrope(q, positions, cfg.rope_theta)
        k = apply_mrope(k, positions, cfg.rope_theta)
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def dense_block(cfg: ModelConfig, x, bp, positions, is_global,
                causal: bool = True):
    B, S, d = x.shape
    h = rms_norm(x, bp["ln1"], cfg.norm_eps)
    q, k, v = _project_qkv(cfg, bp, h, positions)
    if cfg.global_every > 0:
        # lax.cond keeps both paths compiled once inside the layer scan.
        out = lax.cond(
            is_global,
            lambda ops: attn.attention(*ops, causal=causal, window=0),
            lambda ops: attn.attention(*ops, causal=causal,
                                       window=cfg.local_window),
            (q, k, v))
    else:
        out = attn.attention(q, k, v, causal=causal)
    x = x + jnp.einsum("bsh,hd->bsd", out.reshape(B, S, -1), bp["wo"])
    h = rms_norm(x, bp["ln2"], cfg.norm_eps)
    x = x + ffn_apply(cfg, h, bp)
    return x


def forward(cfg: ModelConfig, params, tokens, positions=None,
            prefix_embeds: Optional[jax.Array] = None) -> jax.Array:
    """tokens: (B, S) -> hidden states (B, S_total, d)."""
    x = embed_tokens(params["embed"], tokens, _cdt(cfg))
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    B, S, _ = x.shape
    if positions is None:
        pos1 = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        positions = jnp.stack([pos1] * 3, -1) if cfg.mrope else pos1
    is_glob = jnp.asarray(layer_is_global(cfg))

    block = functools.partial(dense_block, cfg)
    if cfg.remat == "full":
        block = jax.checkpoint(block, static_argnums=())
    elif cfg.remat == "dots":
        block = jax.checkpoint(
            block, policy=jax.checkpoint_policies.checkpoint_dots)

    if cfg.scan_layers:
        def body(carry, inp):
            bp, ig = inp
            carry = constrain_batch(carry)
            return block(carry, bp, positions, ig), None
        x, _ = lax.scan(body, x, (params["blocks"], is_glob))
    else:
        for l in range(cfg.n_layers):
            bp = jax.tree.map(lambda a: a[l], params["blocks"])
            x = block(x, bp, positions, is_glob[l])
    return rms_norm(x, params["final_norm"], cfg.norm_eps)


def loss_fn(cfg: ModelConfig, params, batch) -> jax.Array:
    """batch: {tokens (B,S), labels (B,S), [positions], [prefix_embeds]}."""
    h = forward(cfg, params, batch["tokens"], batch.get("positions"),
                batch.get("prefix_embeds"))
    labels = batch["labels"]
    if batch.get("prefix_embeds") is not None:
        npfx = batch["prefix_embeds"].shape[1]
        pad = jnp.full(labels.shape[:1] + (npfx,), -100, labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
    return chunked_softmax_xent(h, unembed_matrix(cfg, params), labels,
                                chunk=cfg.logits_chunk)


# ----------------------------------------------------------------------------
# decode (serve): full caches for global layers, rolling for local
# ----------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    KV, hd = cfg.n_kv_heads, cfg.hd
    dt = _cdt(cfg)
    is_glob = layer_is_global(cfg)
    n_glob, n_loc = int(is_glob.sum()), int((~is_glob).sum())
    w = cfg.local_window
    cache = {
        "k_glob": jnp.zeros((max(n_glob, 1), batch, max_len, KV, hd), dt),
        "v_glob": jnp.zeros((max(n_glob, 1), batch, max_len, KV, hd), dt),
    }
    if n_loc:
        cache["k_loc"] = jnp.zeros((n_loc, batch, w, KV, hd), dt)
        cache["v_loc"] = jnp.zeros((n_loc, batch, w, KV, hd), dt)
    return cache


def _cache_index_maps(cfg):
    is_glob = layer_is_global(cfg)
    gi, li, g, l = [], [], 0, 0
    for flag in is_glob:
        gi.append(g if flag else 0)
        li.append(l if not flag else 0)
        g += int(flag)
        l += int(not flag)
    return (jnp.asarray(is_glob), jnp.asarray(gi, jnp.int32),
            jnp.asarray(li, jnp.int32))


def decode_step(cfg: ModelConfig, params, cache, tokens, pos):
    """One decode step. tokens: (B, 1); pos: scalar position (int32).

    Returns (logits (B, V), new_cache). The cache for local layers is a
    rolling window indexed pos % window.
    """
    B = tokens.shape[0]
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    x = embed_tokens(params["embed"], tokens, _cdt(cfg))
    pos_b = jnp.broadcast_to(pos, (B, 1))
    positions = jnp.stack([pos_b] * 3, -1) if cfg.mrope else pos_b
    is_glob, gmap, lmap = _cache_index_maps(cfg)
    has_loc = "k_loc" in cache
    w = cache["k_loc"].shape[2] if has_loc else 0

    def body(carry, inp):
        x, cache = carry
        x = constrain_batch(x)
        bp, ig, gidx, lidx = inp
        h = rms_norm(x, bp["ln1"], cfg.norm_eps)
        q, k, v = _project_qkv(cfg, bp, h, positions)

        def glob_path(cache):
            kc = lax.dynamic_update_slice_in_dim(
                cache["k_glob"][gidx], k, pos, axis=1)
            vc = lax.dynamic_update_slice_in_dim(
                cache["v_glob"][gidx], v, pos, axis=1)
            out = attn.decode_attention(q, kc, vc, pos)
            cache = dict(cache)
            cache["k_glob"] = cache["k_glob"].at[gidx].set(kc)
            cache["v_glob"] = cache["v_glob"].at[gidx].set(vc)
            return out, cache

        def loc_path(cache):
            slot = pos % w
            kc = lax.dynamic_update_slice_in_dim(
                cache["k_loc"][lidx], k, slot, axis=1)
            vc = lax.dynamic_update_slice_in_dim(
                cache["v_loc"][lidx], v, slot, axis=1)
            # positions of ring slots: slot s holds absolute index
            # pos - ((slot - s) mod w)
            ages = (slot - jnp.arange(w)) % w
            abs_idx = pos - ages
            s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                           attn._expand_kv(kc, H).astype(jnp.float32)) \
                / jnp.sqrt(hd)
            ok = (abs_idx >= 0) & (abs_idx <= pos) & (abs_idx > pos - w)
            s = jnp.where(ok[None, None, None], s, attn.NEG_INF)
            prob = jax.nn.softmax(s, axis=-1)
            out = jnp.einsum("bhqk,bkhd->bqhd", prob,
                             attn._expand_kv(vc, H).astype(jnp.float32)
                             ).astype(q.dtype)
            cache = dict(cache)
            cache["k_loc"] = cache["k_loc"].at[lidx].set(kc)
            cache["v_loc"] = cache["v_loc"].at[lidx].set(vc)
            return out, cache

        if has_loc:
            out, cache = lax.cond(ig, glob_path, loc_path, cache)
        else:
            out, cache = glob_path(cache)
        x = x + jnp.einsum("bsh,hd->bsd", out.reshape(B, 1, -1), bp["wo"])
        h = rms_norm(x, bp["ln2"], cfg.norm_eps)
        x = x + ffn_apply(cfg, h, bp)
        return (x, cache), None

    (x, cache), _ = lax.scan(
        body, (x, cache),
        (params["blocks"], is_glob, gmap, lmap))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x.astype(jnp.float32),
                        unembed_matrix(cfg, params).astype(jnp.float32))
    return logits[:, 0], cache


def prefill(cfg: ModelConfig, params, tokens) -> tuple[jax.Array, dict]:
    """Full-sequence forward that also fills the KV cache.

    Returns (last-token logits (B, V), cache positioned at S)."""
    B, S = tokens.shape
    x = embed_tokens(params["embed"], tokens, _cdt(cfg))
    pos1 = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    positions = jnp.stack([pos1] * 3, -1) if cfg.mrope else pos1
    is_glob, gmap, lmap = _cache_index_maps(cfg)
    cache = init_cache(cfg, B, S)
    has_loc = "k_loc" in cache
    w = cache["k_loc"].shape[2] if has_loc else 0

    def body(carry, inp):
        x, cache = carry
        x = constrain_batch(x)
        bp, ig, gidx, lidx = inp
        h = rms_norm(x, bp["ln1"], cfg.norm_eps)
        q, k, v = _project_qkv(cfg, bp, h, positions)

        def glob_path(cache):
            out = attn.attention(q, k, v, causal=True)
            cache = dict(cache)
            cache["k_glob"] = cache["k_glob"].at[gidx].set(k)
            cache["v_glob"] = cache["v_glob"].at[gidx].set(v)
            return out, cache

        def loc_path(cache):
            out = attn.attention(q, k, v, causal=True,
                                 window=cfg.local_window)
            cache = dict(cache)
            if has_loc:
                # scatter the trailing window into its ring slots
                keep = min(S, w)
                slots = jnp.arange(S - keep, S) % w
                tail_k = jnp.zeros((B, w) + k.shape[2:], k.dtype) \
                    .at[:, slots].set(k[:, -keep:])
                tail_v = jnp.zeros((B, w) + v.shape[2:], v.dtype) \
                    .at[:, slots].set(v[:, -keep:])
                cache["k_loc"] = cache["k_loc"].at[lidx].set(tail_k)
                cache["v_loc"] = cache["v_loc"].at[lidx].set(tail_v)
            return out, cache

        if has_loc:
            out, cache = lax.cond(ig, glob_path, loc_path, cache)
        else:
            out, cache = glob_path(cache)
        x = x + jnp.einsum("bsh,hd->bsd", out.reshape(B, S, -1), bp["wo"])
        h = rms_norm(x, bp["ln2"], cfg.norm_eps)
        x = x + ffn_apply(cfg, h, bp)
        return (x, cache), None

    (x, cache), _ = lax.scan(
        body, (x, cache), (params["blocks"], is_glob, gmap, lmap))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", x[:, -1].astype(jnp.float32),
                        unembed_matrix(cfg, params).astype(jnp.float32))
    return logits, cache
