"""Unified model API: build_model(cfg) -> Model with init/loss/prefill/
decode_step, uniform across the 6 families (dense, moe, vlm share the
transformer implementation; rwkv6, hymba, whisper have their own).

All functions are pure and jit-friendly; batches are dicts:
  train:   {tokens, labels, [frames], [prefix_embeds], [positions]}
  prefill: {tokens, [frames], [prefix_embeds]}
  decode:  (cache, tokens (B,1), pos scalar)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import hymba, rwkv6, transformer, whisper


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable[[Any], dict]
    loss: Callable[[dict, dict], jax.Array]
    prefill: Callable[[dict, dict], tuple]
    decode_step: Callable[[dict, dict, jax.Array, jax.Array], tuple]
    init_cache: Callable[[int, int], dict]

    def param_count(self, params) -> int:
        return sum(x.size for x in jax.tree.leaves(params))


def build_model(cfg: ModelConfig) -> Model:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        return Model(
            cfg=cfg,
            init=lambda key: transformer.init_params(cfg, key),
            loss=lambda p, b: transformer.loss_fn(cfg, p, b),
            prefill=lambda p, b: transformer.prefill(cfg, p, b["tokens"]),
            decode_step=lambda p, c, t, pos:
                transformer.decode_step(cfg, p, c, t, pos),
            init_cache=lambda b, s: transformer.init_cache(cfg, b, s),
        )
    if fam == "rwkv6":
        return Model(
            cfg=cfg,
            init=lambda key: rwkv6.init_params(cfg, key),
            loss=lambda p, b: rwkv6.loss_fn(cfg, p, b),
            prefill=lambda p, b: rwkv6.prefill(cfg, p, b["tokens"]),
            decode_step=lambda p, c, t, pos:
                rwkv6.decode_step(cfg, p, c, t, pos),
            init_cache=lambda b, s: rwkv6.init_cache(cfg, b, s),
        )
    if fam == "hymba":
        return Model(
            cfg=cfg,
            init=lambda key: hymba.init_params(cfg, key),
            loss=lambda p, b: hymba.loss_fn(cfg, p, b),
            prefill=lambda p, b: hymba.prefill(cfg, p, b["tokens"]),
            decode_step=lambda p, c, t, pos:
                hymba.decode_step(cfg, p, c, t, pos),
            init_cache=lambda b, s: hymba.init_cache(cfg, b, s),
        )
    if fam == "whisper":
        return Model(
            cfg=cfg,
            init=lambda key: whisper.init_params(cfg, key),
            loss=lambda p, b: whisper.loss_fn(cfg, p, b),
            prefill=lambda p, b: whisper.prefill(cfg, p, b["tokens"],
                                                 b["frames"]),
            decode_step=lambda p, c, t, pos:
                whisper.decode_step(cfg, p, c, t, pos),
            init_cache=lambda b, s: whisper.init_cache(cfg, b, s),
        )
    raise ValueError(f"unknown family {fam}")
