"""Mixture-of-Experts FFN: top-k routing with sort-based dropping dispatch.

Used by arctic-480b (128 experts top-2 + dense residual) and phi3.5-moe
(16 experts top-2). The dispatch is capacity-bounded (capacity_factor) and
gather/scatter based - FLOPs scale with top_k, not n_experts, and under
expert parallelism the gather/scatter lowers to all_to_all-style
collectives on the model axis (visible in the roofline's collective term).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models.common import init_dense


def init_moe_params(cfg: ModelConfig, key, n_layers: int) -> dict:
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    dt = jnp.dtype(cfg.param_dtype)
    L = n_layers
    p = {
        "router": init_dense(ks[0], (L, d, E), dtype=jnp.float32),
        "e_gate": init_dense(ks[1], (L, E, d, f), dtype=dt),
        "e_up": init_dense(ks[2], (L, E, d, f), dtype=dt),
        "e_down": init_dense(ks[3], (L, E, f, d), dtype=dt),
    }
    if cfg.moe_dense_ff:
        fd = cfg.moe_dense_ff
        kk = jax.random.split(ks[4], 3)
        p["d_gate"] = init_dense(kk[0], (L, d, fd), dtype=dt)
        p["d_up"] = init_dense(kk[1], (L, d, fd), dtype=dt)
        p["d_down"] = init_dense(kk[2], (L, fd, d), dtype=dt)
    return p


def moe_ffn(cfg: ModelConfig, x: jax.Array, bp: dict) -> jax.Array:
    """x: (B, S, d) -> (B, S, d). bp holds this layer's expert weights."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        bp["router"])
    gates = jax.nn.softmax(logits, axis=-1)
    topv, topi = lax.top_k(gates, k)                     # (B, S, k)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    # Row-local dispatch: routing, scatter and combine happen within each
    # batch row, so with the batch dim sharded over data the dispatch is
    # entirely device-local - only the expert dim (sharded over model)
    # touches the network, via the expert-weight einsums. (The naive
    # global-token dispatch made GSPMD all-reduce the full buffer every
    # layer: 8.2 TB/step measured on phi3.5-moe; see EXPERIMENTS.md SPerf.)
    cap = max(int(round(cfg.capacity_factor * k * S / E)), 1)
    Sk = S * k
    flat_e = topi.reshape(B, Sk)
    order = jnp.argsort(flat_e, axis=1, stable=True)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=1)
    first = jax.vmap(
        lambda se: jnp.searchsorted(se, se, side="left"))(sorted_e)
    pos_in_e = jnp.arange(Sk)[None] - first
    keep = pos_in_e < cap
    pos_c = jnp.where(keep, pos_in_e, cap)               # cap = drop slot
    token_of = order // k                                # (B, Sk)

    from repro.models.shardctx import constrain, constrain_batch, \
        get_batch_axes
    from jax.sharding import PartitionSpec as P
    # Expert-parallel mode (arctic: 128e) shards the expert dim of the
    # dispatch buffers over 'model'; TP-inside-experts mode (phi: 16e)
    # keeps them batch-sharded only (see train.step.param_pspec).
    ep_mode = E >= 64
    ba = get_batch_axes()

    def _cst(t):
        if not ba or ep_mode:
            # EP mode: leave placement to GSPMD - measured better than
            # forcing either batch- or expert-sharded dispatch buffers
            # (EXPERIMENTS.md SPerf, arctic iterations).
            return t
        return constrain_batch(t)

    bidx = jnp.arange(B)[:, None]
    vals = jnp.take_along_axis(x, token_of[..., None], axis=1)
    buf = jnp.zeros((B, E, cap + 1, d), x.dtype)
    buf = buf.at[bidx, sorted_e, pos_c].set(
        vals * keep[..., None].astype(x.dtype))
    buf = _cst(buf)
    eb = buf[:, :, :cap]

    g = jnp.einsum("becd,edf->becf", eb, bp["e_gate"])
    u = jnp.einsum("becd,edf->becf", eb, bp["e_up"])
    out_e = jnp.einsum("becf,efd->becd", jax.nn.silu(g) * u,
                       bp["e_down"])
    out_e = _cst(out_e)
    out_e = jnp.pad(out_e, ((0, 0), (0, 0), (0, 1), (0, 0)))
    w = jnp.take_along_axis(topv.reshape(B, Sk), order, axis=1) \
        .astype(x.dtype)
    contrib = out_e[bidx, sorted_e, pos_c] * \
        (w * keep.astype(x.dtype))[..., None]
    y = jnp.zeros((B, S, d), x.dtype)
    y = y.at[bidx, token_of].add(contrib)
    out = y

    if cfg.moe_dense_ff:
        from repro.models.common import swiglu
        out = out + swiglu(x, bp["d_gate"], bp["d_up"], bp["d_down"])
    return out


def aux_load_balance_loss(cfg: ModelConfig, x: jax.Array,
                          router: jax.Array) -> jax.Array:
    """Switch-style load-balancing auxiliary loss for one layer."""
    T = x.shape[0] * x.shape[1]
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), router)
    gates = jax.nn.softmax(logits, -1).reshape(T, -1)
    topi = jnp.argmax(gates, -1)
    frac_tokens = jnp.mean(
        jax.nn.one_hot(topi, cfg.n_experts, dtype=jnp.float32), axis=0)
    frac_probs = gates.mean(0)
    return cfg.n_experts * jnp.sum(frac_tokens * frac_probs)
