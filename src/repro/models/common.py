"""Shared building blocks: norms, RoPE / M-RoPE, MLPs, embeddings, losses."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax


def cast(x, dtype_str):
    return x.astype(jnp.dtype(dtype_str))


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * lax.rsqrt(var + eps) * (1.0 + weight.astype(jnp.float32))
    return out.astype(dt)


def swiglu(x: jax.Array, w_gate, w_up, w_down) -> jax.Array:
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * u, w_down)


def gelu_mlp(x: jax.Array, w_in, b_in, w_out, b_out) -> jax.Array:
    h = jax.nn.gelu(jnp.einsum("...d,df->...f", x, w_in) + b_in)
    return jnp.einsum("...f,fd->...d", h, w_out) + b_out


# ----------------------------------------------------------------------------
# Rotary embeddings (standard + Qwen2-VL M-RoPE)
# ----------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float
               ) -> jax.Array:
    """x: (B, S, H, hd); positions: (B, S) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                        # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs   # (B,S,hd/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions: jax.Array, theta: float,
                sections=(16, 24, 24)) -> jax.Array:
    """Qwen2-VL multimodal RoPE: positions (B, S, 3) = (t, h, w) ids.

    The hd/2 frequency channels are split into three sections rotated by
    the temporal / height / width position respectively (text tokens carry
    identical ids in all three, reducing to standard RoPE).
    """
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                        # (hd/2,)
    half = hd // 2
    secs = np.asarray(sections, dtype=np.int64)
    secs = (secs * half // secs.sum())
    secs[-1] = half - secs[:-1].sum()
    sel = np.concatenate([np.full(s, i) for i, s in enumerate(secs)])
    pos3 = positions.astype(jnp.float32)                 # (B,S,3)
    pos = jnp.take_along_axis(
        pos3, jnp.asarray(sel)[None, None, :].repeat(pos3.shape[0], 0)
        .repeat(pos3.shape[1], 1), axis=-1)              # (B,S,hd/2)
    ang = pos * freqs
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------------
# Vocab-chunked softmax cross-entropy (never materializes full logits)
# ----------------------------------------------------------------------------

def chunked_softmax_xent(h: jax.Array, w_out: jax.Array, labels: jax.Array,
                         chunk: int = 256, z_loss: float = 0.0) -> jax.Array:
    """Mean token NLL of labels under softmax(h @ w_out).

    h: (B, S, d); w_out: (d, V); labels: (B, S) int32; label -100 = masked.
    Scans over sequence chunks so the logits tensor is (B, chunk, V) at a
    time - essential for 262k vocabularies at 4k+ sequance lengths.
    """
    B, S, d = h.shape
    pad = (-S) % chunk
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)),
                         constant_values=-100)
    nchunks = h.shape[1] // chunk
    hc = h.reshape(B, nchunks, chunk, d).swapaxes(0, 1)
    lc = labels.reshape(B, nchunks, chunk).swapaxes(0, 1)

    def body(carry, inp):
        nll_sum, z_sum, count = carry
        hx, lx = inp
        logits = jnp.einsum("bsd,dv->bsv", hx.astype(jnp.float32),
                            w_out.astype(jnp.float32))
        lse = jax.nn.logsumexp(logits, axis=-1)
        mask = lx >= 0
        safe = jnp.where(mask, lx, 0)
        gold = jnp.take_along_axis(logits, safe[..., None], -1)[..., 0]
        nll = jnp.where(mask, lse - gold, 0.0)
        zl = jnp.where(mask, lse * lse, 0.0)
        return (nll_sum + nll.sum(), z_sum + zl.sum(),
                count + mask.sum()), None

    (nll, zl, cnt), _ = lax.scan(body, (0.0, 0.0, 0), (hc, lc))
    cnt = jnp.maximum(cnt, 1)
    return nll / cnt + z_loss * zl / cnt


def embed_tokens(embedding: jax.Array, tokens: jax.Array,
                 compute_dtype) -> jax.Array:
    return embedding[tokens].astype(compute_dtype)


def init_dense(key, shape, scale=None, dtype=jnp.float32):
    fan_in = shape[0] if len(shape) >= 2 else 1
    scale = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def chunked_scan(f, init, xs, chunk: int):
    """lax.scan over time with chunked rematerialization.

    Equivalent to lax.scan(f, init, xs) but the backward pass stores the
    carry only at chunk boundaries and recomputes inside each chunk -
    O(S/chunk * |carry| + chunk * |step|) memory instead of O(S * |carry|).
    xs: pytree with leading time axis; returns (carry, ys) like lax.scan.
    """
    S = jax.tree.leaves(xs)[0].shape[0]
    chunk = max(1, min(chunk, S))
    pad = (-S) % chunk
    if pad:
        xs = jax.tree.map(
            lambda a: jnp.pad(a, [(0, pad)] + [(0, 0)] * (a.ndim - 1)), xs)
    nc = (S + pad) // chunk
    xs_c = jax.tree.map(
        lambda a: a.reshape((nc, chunk) + a.shape[1:]), xs)

    @jax.checkpoint
    def outer(carry, xc):
        return lax.scan(f, carry, xc)

    carry, ys = lax.scan(outer, init, xs_c)
    ys = jax.tree.map(
        lambda a: a.reshape((nc * chunk,) + a.shape[2:])[:S], ys)
    return carry, ys
