"""Whisper-style encoder-decoder backbone.

The conv audio frontend is a STUB per the assignment: inputs provide
precomputed frame embeddings (B, n_audio_frames, d) - what the two conv
layers would produce from the mel spectrogram. The transformer backbone
(bidirectional encoder, causal decoder with cross-attention) is complete.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models.common import (chunked_softmax_xent, embed_tokens,
                                 init_dense, rms_norm, swiglu)
from repro.models.transformer import init_block_params, _project_qkv


def init_params(cfg: ModelConfig, key) -> dict:
    ks = jax.random.split(key, 6)
    dt = jnp.dtype(cfg.param_dtype)
    d = cfg.d_model
    return {
        "embed": init_dense(ks[0], (cfg.vocab_size, d), scale=0.02,
                            dtype=dt),
        "pos_embed": init_dense(ks[1], (cfg.n_audio_frames, d),
                                scale=0.02, dtype=dt),
        "enc_blocks": init_block_params(cfg, ks[2], cfg.encoder_layers),
        "enc_norm": jnp.zeros((d,), dt),
        "dec_blocks": init_block_params(cfg, ks[3], cfg.n_layers,
                                        cross_attn=True),
        "final_norm": jnp.zeros((d,), dt),
        "lm_head": init_dense(ks[4], (d, cfg.vocab_size), scale=0.02,
                              dtype=dt),
    }


def encode(cfg: ModelConfig, params, frames: jax.Array) -> jax.Array:
    """frames: (B, F, d) stubbed conv output -> encoder states (B, F, d)."""
    B, F, d = frames.shape
    x = frames.astype(jnp.dtype(cfg.compute_dtype)) \
        + params["pos_embed"][None, :F].astype(frames.dtype)
    positions = jnp.broadcast_to(jnp.arange(F)[None], (B, F))

    def body(carry, bp):
        from repro.models.shardctx import constrain_batch
        x = constrain_batch(carry)
        h = rms_norm(x, bp["ln1"], cfg.norm_eps)
        q, k, v = _project_qkv(cfg, bp, h, positions)
        out = attn.attention(q, k, v, causal=False)
        x = x + jnp.einsum("bsh,hd->bsd", out.reshape(B, F, -1), bp["wo"])
        h = rms_norm(x, bp["ln2"], cfg.norm_eps)
        x = x + swiglu(h, bp["w_gate"], bp["w_up"], bp["w_down"])
        return x, None

    x, _ = lax.scan(body, x, params["enc_blocks"])
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _decoder_block(cfg, bp, x, positions, enc, self_out):
    """Shared decoder block body; self_out is the self-attn result."""
    B, S, d = x.shape
    x = x + jnp.einsum("bsh,hd->bsd", self_out.reshape(B, S, -1),
                       bp["wo"])
    # cross attention
    h = rms_norm(x, bp["ln_x"], cfg.norm_eps)
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = jnp.einsum("bsd,dh->bsh", h, bp["xq"]).reshape(B, S, H, hd)
    k = jnp.einsum("bfd,dh->bfh", enc, bp["xk"]).reshape(
        B, enc.shape[1], KV, hd)
    v = jnp.einsum("bfd,dh->bfh", enc, bp["xv"]).reshape(
        B, enc.shape[1], KV, hd)
    out = attn.attention(q, k, v, causal=False)
    x = x + jnp.einsum("bsh,hd->bsd", out.reshape(B, S, -1), bp["xo"])
    h = rms_norm(x, bp["ln2"], cfg.norm_eps)
    return x + swiglu(h, bp["w_gate"], bp["w_up"], bp["w_down"])


def forward(cfg: ModelConfig, params, tokens, frames) -> jax.Array:
    """Teacher-forced decoder over encoder(frames)."""
    enc = encode(cfg, params, frames)
    B, S = tokens.shape
    x = embed_tokens(params["embed"], tokens,
                     jnp.dtype(cfg.compute_dtype))
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def body(carry, bp):
        from repro.models.shardctx import constrain_batch
        x = constrain_batch(carry)
        h = rms_norm(x, bp["ln1"], cfg.norm_eps)
        q, k, v = _project_qkv(cfg, bp, h, positions)
        self_out = attn.attention(q, k, v, causal=True)
        return _decoder_block(cfg, bp, x, positions, enc, self_out), None

    x, _ = lax.scan(body, x, params["dec_blocks"])
    return rms_norm(x, params["final_norm"], cfg.norm_eps)


def loss_fn(cfg: ModelConfig, params, batch) -> jax.Array:
    h = forward(cfg, params, batch["tokens"], batch["frames"])
    return chunked_softmax_xent(h, params["lm_head"], batch["labels"],
                                chunk=cfg.logits_chunk)


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    KV, hd, L = cfg.n_kv_heads, cfg.hd, cfg.n_layers
    dt = jnp.dtype(cfg.compute_dtype)
    F = cfg.n_audio_frames
    return {
        "k": jnp.zeros((L, batch, max_len, KV, hd), dt),
        "v": jnp.zeros((L, batch, max_len, KV, hd), dt),
        "xk": jnp.zeros((L, batch, F, KV, hd), dt),
        "xv": jnp.zeros((L, batch, F, KV, hd), dt),
    }


def prefill(cfg: ModelConfig, params, tokens, frames):
    """Encode audio, run the decoder prompt, fill self+cross caches."""
    enc = encode(cfg, params, frames)
    B, S = tokens.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    x = embed_tokens(params["embed"], tokens,
                     jnp.dtype(cfg.compute_dtype))
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def body(carry, bp):
        x = carry
        h = rms_norm(x, bp["ln1"], cfg.norm_eps)
        q, k, v = _project_qkv(cfg, bp, h, positions)
        self_out = attn.attention(q, k, v, causal=True)
        xk = jnp.einsum("bfd,dh->bfh", enc, bp["xk"]).reshape(
            B, enc.shape[1], KV, hd)
        xv = jnp.einsum("bfd,dh->bfh", enc, bp["xv"]).reshape(
            B, enc.shape[1], KV, hd)
        x = _decoder_block(cfg, bp, x, positions, enc, self_out)
        return x, (k, v, xk, xv)

    x, (k, v, xk, xv) = lax.scan(body, x, params["dec_blocks"])
    cache = {"k": k, "v": v, "xk": xk, "xv": xv}
    x = rms_norm(x[:, -1], params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", x.astype(jnp.float32),
                        params["lm_head"].astype(jnp.float32))
    return logits, cache


def decode_step(cfg: ModelConfig, params, cache, tokens, pos):
    B = tokens.shape[0]
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    x = embed_tokens(params["embed"], tokens,
                     jnp.dtype(cfg.compute_dtype))
    positions = jnp.broadcast_to(pos, (B, 1))

    def body(carry, inp):
        x = carry
        bp, kc, vc, xk, xv = inp
        h = rms_norm(x, bp["ln1"], cfg.norm_eps)
        q, k, v = _project_qkv(cfg, bp, h, positions)
        kc = lax.dynamic_update_slice_in_dim(kc, k, pos, axis=1)
        vc = lax.dynamic_update_slice_in_dim(vc, v, pos, axis=1)
        self_out = attn.decode_attention(q, kc, vc, pos)
        x = x + jnp.einsum("bsh,hd->bsd",
                           self_out.reshape(B, 1, -1), bp["wo"])
        h = rms_norm(x, bp["ln_x"], cfg.norm_eps)
        q2 = jnp.einsum("bsd,dh->bsh", h, bp["xq"]).reshape(B, 1, H, hd)
        out = attn.decode_attention(q2, xk, xv, xk.shape[1] - 1)
        x = x + jnp.einsum("bsh,hd->bsd", out.reshape(B, 1, -1), bp["xo"])
        h = rms_norm(x, bp["ln2"], cfg.norm_eps)
        x = x + swiglu(h, bp["w_gate"], bp["w_up"], bp["w_down"])
        return x, (kc, vc)

    x, (kc, vc) = lax.scan(
        body, x, (params["dec_blocks"], cache["k"], cache["v"],
                  cache["xk"], cache["xv"]))
    cache = dict(cache, k=kc, v=vc)
    x = rms_norm(x[:, 0], params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", x.astype(jnp.float32),
                        params["lm_head"].astype(jnp.float32))
    return logits, cache
