"""GQA attention: flash-style chunked training path + KV-cache decode path.

The chunked path (online softmax over KV blocks inside a scan over Q
blocks) is the pure-jnp oracle for kernels/flash_attention and keeps
activation memory O(q_chunk * kv_chunk) - required for 32k prefill at a
262k-vocab model's batch sizes. All softmax math in fp32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

NEG_INF = -1e30


def _expand_kv(k: jax.Array, n_heads: int) -> jax.Array:
    """(B, S, KV, hd) -> (B, S, H, hd) by repeating each KV head."""
    rep = n_heads // k.shape[2]
    if rep == 1:
        return k
    return jnp.repeat(k, rep, axis=2)


def _mask(iq, jk, causal: bool, window: int):
    ok = jnp.ones((iq.shape[0], jk.shape[0]), jnp.bool_)
    if causal:
        ok = ok & (jk[None, :] <= iq[:, None])
    if window > 0:
        ok = ok & (jk[None, :] > iq[:, None] - window)
    return ok


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True, window: int = 0,
                      q_chunk: int = 256, kv_chunk: int = 1024,
                      q_offset: int = 0) -> jax.Array:
    """q: (B,Sq,H,hd); k,v: (B,Skv,KV,hd) -> (B,Sq,H,hd)."""
    B, Sq, H, hd = q.shape
    Skv = k.shape[1]
    k = _expand_kv(k, H)
    v = _expand_kv(v, H)
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)

    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Skv)
    pad_q = (-Sq) % q_chunk
    pad_kv = (-Skv) % kv_chunk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
    nq, nkv = q.shape[1] // q_chunk, k.shape[1] // kv_chunk

    # (nq, B, H, cq, hd) blocks
    qb = q.reshape(B, nq, q_chunk, H, hd).transpose(1, 0, 3, 2, 4)
    kb = k.reshape(B, nkv, kv_chunk, H, hd).transpose(1, 0, 3, 2, 4)
    vb = v.reshape(B, nkv, kv_chunk, H, hd).transpose(1, 0, 3, 2, 4)

    # Sliding-window block skipping: query block qi only needs KV blocks
    # covering [iq_min - window, iq_max], a FIXED count of relative block
    # offsets - compute drops from O(S^2) to O(S * window) (hymba and
    # gemma3 local layers at 32k+). Plain causal keeps the full masked
    # scan (its needed span varies per q block).
    windowed = window > 0
    if windowed:
        span = window + q_chunk + kv_chunk
        n_rel = min(nkv, (span + kv_chunk - 1) // kv_chunk + 1)

    def q_body(_, qi_blk):
        qi, blk = qi_blk
        iq = q_offset + qi * q_chunk + jnp.arange(q_chunk)
        hi_blk = (q_offset + (qi + 1) * q_chunk - 1) // kv_chunk \
            if causal else nkv - 1

        def kv_step(carry, kvj, kblk, vblk, extra_ok):
            m, l, acc = carry
            jk = kvj * kv_chunk + jnp.arange(kv_chunk)
            s = jnp.einsum("bhqd,bhkd->bhqk", blk.astype(jnp.float32),
                           kblk.astype(jnp.float32)) * scale
            ok = _mask(iq, jk, causal, window)
            ok = ok & (jk < Skv)[None, :] & extra_ok
            s = jnp.where(ok[None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p, vblk.astype(jnp.float32))
            return (m_new, l_new, acc_new)

        m0 = jnp.full((B, H, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, H, q_chunk, hd), jnp.float32)

        if windowed:
            def kv_body(carry, rel):
                kvj = jnp.clip(hi_blk - rel, 0, nkv - 1)
                kblk = lax.dynamic_index_in_dim(kb, kvj, 0, False)
                vblk = lax.dynamic_index_in_dim(vb, kvj, 0, False)
                return kv_step(carry, kvj, kblk, vblk,
                               rel <= hi_blk), None
            (m, l, acc), _ = lax.scan(kv_body, (m0, l0, a0),
                                      jnp.arange(n_rel))
        else:
            def kv_body(carry, kv):
                kvj, kblk, vblk = kv
                return kv_step(carry, kvj, kblk, vblk, True), None
            (m, l, acc), _ = lax.scan(
                kv_body, (m0, l0, a0), (jnp.arange(nkv), kb, vb))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out

    _, ob = lax.scan(q_body, None, (jnp.arange(nq), qb))
    out = ob.transpose(1, 0, 3, 2, 4).reshape(B, nq * q_chunk, H, hd)
    return out[:, :Sq].astype(q.dtype)


def direct_attention(q, k, v, *, causal=True, window: int = 0,
                     q_offset: int = 0) -> jax.Array:
    """Reference quadratic path for short sequences / tests."""
    B, Sq, H, hd = q.shape
    Skv = k.shape[1]
    k = _expand_kv(k, H)
    v = _expand_kv(v, H)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(hd)
    iq = q_offset + jnp.arange(Sq)
    jk = jnp.arange(Skv)
    ok = _mask(iq, jk, causal, window)
    s = jnp.where(ok[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def decode_attention(q, k_cache, v_cache, pos, *, window: int = 0
                     ) -> jax.Array:
    """One-token attention against a (B, Smax, KV, hd) cache.

    pos: scalar current position (the cache holds entries [0, pos]).
    """
    B, one, H, hd = q.shape
    Smax = k_cache.shape[1]
    k = _expand_kv(k_cache, H)
    v = _expand_kv(v_cache, H)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(hd)
    jk = jnp.arange(Smax)
    ok = jk <= pos
    if window > 0:
        ok = ok & (jk > pos - window)
    s = jnp.where(ok[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def attention(q, k, v, *, causal=True, window: int = 0, q_offset: int = 0,
              chunked_threshold: int = 1024) -> jax.Array:
    if q.shape[1] <= chunked_threshold and k.shape[1] <= chunked_threshold:
        return direct_attention(q, k, v, causal=causal, window=window,
                                q_offset=q_offset)
    return chunked_attention(q, k, v, causal=causal, window=window,
                             q_offset=q_offset)
