"""RWKV-6 "Finch": attention-free RNN with data-dependent decay.

Per layer: time-mix (the wkv recurrence over a per-head (hd x hd) state
with data-dependent decay w_t, driven by r/k/v/g projections with
token-shift) and channel-mix (token-shifted squared-ReLU MLP). State is
O(1) in sequence length, so `long_500k` decode carries only
(L, B, H, hd, hd) + shift states - no KV cache.

Training runs the recurrence with lax.scan over time (one compiled step);
decode reuses the same cell on a single token.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models.common import (chunked_scan, chunked_softmax_xent,
                                 embed_tokens, init_dense, rms_norm)


def _dims(cfg: ModelConfig):
    hd = cfg.ssm_state or 64
    H = cfg.d_model // hd
    return H, hd


def init_params(cfg: ModelConfig, key) -> dict:
    d, f, L = cfg.d_model, cfg.d_ff, cfg.n_layers
    H, hd = _dims(cfg)
    ks = jax.random.split(key, 16)
    dt = jnp.dtype(cfg.param_dtype)

    def W(i, shape):
        return init_dense(ks[i], (L,) + shape, dtype=dt)

    blocks = {
        "ln1": jnp.zeros((L, d), dt),
        "mix_rkvwg": 0.5 * jnp.ones((L, 5, d), dt),   # token-shift lerp
        "wr": W(0, (d, d)), "wk": W(1, (d, d)), "wv": W(2, (d, d)),
        "wg": W(3, (d, d)), "wo": W(4, (d, d)),
        # data-dependent decay: low-rank w = base + tanh(x A) B
        "w_base": -6.0 * jnp.ones((L, H, hd), jnp.float32),
        "w_lora_a": W(5, (d, 64)),
        "w_lora_b": init_dense(ks[6], (L, 64, d), scale=0.01, dtype=dt),
        "bonus": jnp.zeros((L, H, hd), jnp.float32),   # "u" first-token boost
        "ln_x": jnp.zeros((L, d), dt),                 # per-head group norm
        "ln2": jnp.zeros((L, d), dt),
        "ck": W(7, (d, f)), "cv": W(8, (f, d)), "cr": W(9, (d, d)),
        "mix_c": 0.5 * jnp.ones((L, 2, d), dt),
    }
    params = {
        "embed": init_dense(ks[10], (cfg.vocab_size, d), scale=0.02,
                            dtype=dt),
        "blocks": blocks,
        "final_norm": jnp.zeros((d,), dt),
        "lm_head": init_dense(ks[11], (d, cfg.vocab_size), scale=0.02,
                              dtype=dt),
    }
    return params


def _time_mix_cell(cfg, bp, x_t, x_prev, state):
    """One token of wkv6. x_t: (B, d); state: (B, H, hd, hd)."""
    H, hd = _dims(cfg)
    B, d = x_t.shape
    mix = bp["mix_rkvwg"].astype(jnp.float32)            # (5, d)
    xf, pf = x_t.astype(jnp.float32), x_prev.astype(jnp.float32)
    sx = [pf + mix[i] * (xf - pf) for i in range(5)]
    r = (sx[0] @ bp["wr"].astype(jnp.float32)).reshape(B, H, hd)
    k = (sx[1] @ bp["wk"].astype(jnp.float32)).reshape(B, H, hd)
    v = (sx[2] @ bp["wv"].astype(jnp.float32)).reshape(B, H, hd)
    g = jax.nn.silu(sx[4] @ bp["wg"].astype(jnp.float32))
    # data-dependent decay (Finch): w_t in (0,1), per channel
    w_dd = jnp.tanh(sx[3] @ bp["w_lora_a"].astype(jnp.float32)) \
        @ bp["w_lora_b"].astype(jnp.float32)
    w = jnp.exp(-jnp.exp(bp["w_base"].reshape(1, H, hd)
                         + w_dd.reshape(B, H, hd)))
    u = bp["bonus"].reshape(1, H, hd)
    # out_t = r . (S + u * k^T v);  S' = diag(w) S + k^T v
    kv = jnp.einsum("bhk,bhv->bhkv", k, v)
    out = jnp.einsum("bhk,bhkv->bhv", r, state + u[..., None] * kv)
    new_state = w[..., None] * state + kv
    out = rms_norm(out.reshape(B, H * hd), bp["ln_x"], cfg.norm_eps)
    out = (out * g) @ bp["wo"].astype(jnp.float32)
    return out.astype(x_t.dtype), new_state


def _channel_mix_cell(cfg, bp, x_t, x_prev):
    mix = bp["mix_c"].astype(jnp.float32)
    xf, pf = x_t.astype(jnp.float32), x_prev.astype(jnp.float32)
    xk = pf + mix[0] * (xf - pf)
    xr = pf + mix[1] * (xf - pf)
    kk = jnp.square(jax.nn.relu(xk @ bp["ck"].astype(jnp.float32)))
    rr = jax.nn.sigmoid(xr @ bp["cr"].astype(jnp.float32))
    return (rr * (kk @ bp["cv"].astype(jnp.float32))).astype(x_t.dtype)


def _layer_parallel(cfg, bp, x):
    """One rwkv6 layer over (B, S, d), sequence-parallel formulation.

    All projections (r/k/v/g/w, channel-mix) are batched matmuls over the
    whole sequence - token shift is a parallel roll - so TP collectives
    happen once per layer, not once per token. Only the elementwise wkv
    recurrence runs under (chunk-rematted) lax.scan, with no matmuls or
    collectives in its body. Returns (x_out, (tshift, cshift, wkv_state)).
    """
    B, S, d = x.shape
    H, hd = _dims(cfg)

    h = rms_norm(x, bp["ln1"], cfg.norm_eps).astype(jnp.float32)
    h_prev = jnp.pad(h, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    mix = bp["mix_rkvwg"].astype(jnp.float32)            # (5, d)
    sx = [h_prev + mix[i] * (h - h_prev) for i in range(5)]
    r = (sx[0] @ bp["wr"].astype(jnp.float32)).reshape(B, S, H, hd)
    k = (sx[1] @ bp["wk"].astype(jnp.float32)).reshape(B, S, H, hd)
    v = (sx[2] @ bp["wv"].astype(jnp.float32)).reshape(B, S, H, hd)
    g = jax.nn.silu(sx[4] @ bp["wg"].astype(jnp.float32))
    w_dd = jnp.tanh(sx[3] @ bp["w_lora_a"].astype(jnp.float32)) \
        @ bp["w_lora_b"].astype(jnp.float32)
    w = jnp.exp(-jnp.exp(bp["w_base"].reshape(1, 1, H, hd)
                         + w_dd.reshape(B, S, H, hd)))
    u = bp["bonus"].reshape(1, H, hd)

    def step(state, inp):
        r_t, k_t, v_t, w_t = inp                     # (B,H,hd) each
        kv = jnp.einsum("bhk,bhv->bhkv", k_t, v_t)
        out = jnp.einsum("bhk,bhkv->bhv", r_t, state + u[..., None] * kv)
        state = w_t[..., None] * state + kv
        return state, out

    init = jnp.zeros((B, H, hd, hd), jnp.float32)
    if cfg.use_wkv_kernel:
        # Pallas wkv kernel: state stays in VMEM across the sequence
        # (forward/serving path; training uses the differentiable scan).
        from repro.kernels.wkv.ops import wkv as wkv_kernel
        import jax as _jax
        interp = _jax.default_backend() != "tpu"
        outs_bshd, wkv = wkv_kernel(
            r, k, v, w, bp["bonus"].astype(jnp.float32).reshape(H, hd),
            interpret=interp)
        outs = outs_bshd.swapaxes(0, 1)
    else:
        wkv, outs = chunked_scan(
            step, init,
            (r.swapaxes(0, 1), k.swapaxes(0, 1), v.swapaxes(0, 1),
             w.swapaxes(0, 1)), cfg.ssm_chunk)
    out = rms_norm(outs.swapaxes(0, 1).reshape(B, S, H * hd),
                   bp["ln_x"], cfg.norm_eps)
    out = (out * g) @ bp["wo"].astype(jnp.float32)
    x = x + out.astype(x.dtype)
    tshift = h[:, -1].astype(x.dtype)

    # channel mix: fully parallel (token shift is a roll)
    h2 = rms_norm(x, bp["ln2"], cfg.norm_eps).astype(jnp.float32)
    h2_prev = jnp.pad(h2, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    mixc = bp["mix_c"].astype(jnp.float32)
    xk = h2_prev + mixc[0] * (h2 - h2_prev)
    xr = h2_prev + mixc[1] * (h2 - h2_prev)
    kk = jnp.square(jax.nn.relu(xk @ bp["ck"].astype(jnp.float32)))
    rr = jax.nn.sigmoid(xr @ bp["cr"].astype(jnp.float32))
    x = x + (rr * (kk @ bp["cv"].astype(jnp.float32))).astype(x.dtype)
    cshift = h2[:, -1].astype(x.dtype)
    return x, (tshift, cshift, wkv)


def forward(cfg: ModelConfig, params, tokens, positions=None,
            prefix_embeds=None) -> jax.Array:
    x = embed_tokens(params["embed"], tokens,
                     jnp.dtype(cfg.compute_dtype))

    def body(carry, bp):
        from repro.models.shardctx import constrain_batch
        out, _states = _layer_parallel(cfg, bp, constrain_batch(carry))
        return out, None

    x, _ = lax.scan(body, x, params["blocks"])
    return rms_norm(x, params["final_norm"], cfg.norm_eps)


def loss_fn(cfg: ModelConfig, params, batch) -> jax.Array:
    h = forward(cfg, params, batch["tokens"])
    return chunked_softmax_xent(h, params["lm_head"], batch["labels"],
                                chunk=cfg.logits_chunk)


# ----------------------------------------------------------------------------
# serving: O(1) state
# ----------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    H, hd = _dims(cfg)
    L, d = cfg.n_layers, cfg.d_model
    dt = jnp.dtype(cfg.compute_dtype)
    return {
        "wkv": jnp.zeros((L, batch, H, hd, hd), jnp.float32),
        "tshift": jnp.zeros((L, batch, d), dt),
        "cshift": jnp.zeros((L, batch, d), dt),
    }


def decode_step(cfg: ModelConfig, params, cache, tokens, pos):
    """tokens: (B, 1) -> (logits (B, V), cache)."""
    B = tokens.shape[0]
    x = embed_tokens(params["embed"], tokens,
                     jnp.dtype(cfg.compute_dtype))[:, 0]

    def body(carry, inp):
        x = carry
        bp, wkv, tsh, csh = inp
        h = rms_norm(x, bp["ln1"], cfg.norm_eps)
        out, wkv = _time_mix_cell(cfg, bp, h, tsh, wkv)
        x = x + out
        h2 = rms_norm(x, bp["ln2"], cfg.norm_eps)
        out2 = _channel_mix_cell(cfg, bp, h2, csh)
        x = x + out2
        return x, (wkv, h, h2)

    x, (wkv, tsh, csh) = lax.scan(
        body, x, (params["blocks"], cache["wkv"], cache["tshift"],
                  cache["cshift"]))
    cache = {"wkv": wkv, "tshift": tsh, "cshift": csh}
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", x.astype(jnp.float32),
                        params["lm_head"].astype(jnp.float32))
    return logits, cache


def prefill(cfg: ModelConfig, params, tokens):
    """Parallel prefill: sequence-parallel layers, recurrent state out."""
    B, S = tokens.shape
    x = embed_tokens(params["embed"], tokens,
                     jnp.dtype(cfg.compute_dtype))

    def layer_body(carry, bp):
        from repro.models.shardctx import constrain_batch
        out, (tsh, csh, wkv) = _layer_parallel(cfg, bp,
                                               constrain_batch(carry))
        return out, (wkv, tsh, csh)

    x, (wkv, tsh, csh) = lax.scan(layer_body, x, params["blocks"])
    cache = {"wkv": wkv, "tshift": tsh, "cshift": csh}
    x = rms_norm(x[:, -1], params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", x.astype(jnp.float32),
                        params["lm_head"].astype(jnp.float32))
    return logits, cache
