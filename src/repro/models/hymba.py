"""Hymba: hybrid-head blocks - attention heads and Mamba (selective SSM)
heads run *in parallel* on the same input, their normalized outputs are
averaged (arXiv:2411.13676). Attention uses a sliding window (the SSM
branch carries the long-range state), so decode state is
O(window + d*ssm_state) per layer - sub-quadratic for long_500k.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models.common import (chunked_scan, chunked_softmax_xent,
                                 embed_tokens, init_dense, rms_norm, swiglu)
from repro.models.transformer import _project_qkv


def init_params(cfg: ModelConfig, key) -> dict:
    d, f, L = cfg.d_model, cfg.d_ff, cfg.n_layers
    N = cfg.ssm_state
    ks = jax.random.split(key, 16)
    dt = jnp.dtype(cfg.param_dtype)
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd

    def W(i, shape):
        return init_dense(ks[i], (L,) + shape, dtype=dt)

    blocks = {
        "ln1": jnp.zeros((L, d), dt),
        # attention branch
        "wq": W(0, (d, H * hd)), "wk": W(1, (d, KV * hd)),
        "wv": W(2, (d, KV * hd)), "wo": W(3, (H * hd, d)),
        "attn_norm": jnp.zeros((L, d), dt),
        # mamba branch (d_inner = d)
        "m_in": W(4, (d, 2 * d)),                  # x and gate z
        "m_conv": init_dense(ks[5], (L, 4, d), scale=0.5, dtype=dt),
        "m_xbc": W(6, (d, 2 * N + d)),             # B, C, Delta projections
        "m_dt_bias": jnp.zeros((L, d), jnp.float32),
        "m_alog": jnp.broadcast_to(
            jnp.log(jnp.arange(1, N + 1, dtype=jnp.float32)), (L, d, N)),
        "m_d": jnp.ones((L, d), jnp.float32),
        "m_out": W(7, (d, d)),
        "mamba_norm": jnp.zeros((L, d), dt),
        # shared mlp
        "ln2": jnp.zeros((L, d), dt),
        "w_gate": W(8, (d, f)), "w_up": W(9, (d, f)),
        "w_down": W(10, (f, d)),
    }
    return {
        "embed": init_dense(ks[11], (cfg.vocab_size, d), scale=0.02,
                            dtype=dt),
        "blocks": blocks,
        "final_norm": jnp.zeros((d,), dt),
        "lm_head": init_dense(ks[12], (d, cfg.vocab_size), scale=0.02,
                              dtype=dt),
    }


def _mamba_scan(cfg, bp, h, conv_state=None, ssm_state=None):
    """Selective SSM over (B, S, d). Returns (out, conv_state, ssm_state)."""
    B, S, d = h.shape
    N = cfg.ssm_state
    xz = jnp.einsum("bsd,de->bse", h, bp["m_in"])
    x, z = jnp.split(xz, 2, axis=-1)
    # depthwise causal conv, kernel 4
    k = bp["m_conv"].astype(jnp.float32)               # (4, d)
    xp = x.astype(jnp.float32)
    if conv_state is None:
        conv_in = jnp.pad(xp, ((0, 0), (3, 0), (0, 0)))
    else:
        conv_in = jnp.concatenate([conv_state.astype(jnp.float32), xp], 1)
    xc = sum(conv_in[:, i:i + S] * k[i] for i in range(4))
    new_conv_state = conv_in[:, -3:].astype(h.dtype)
    xc = jax.nn.silu(xc)

    bcd = jnp.einsum("bsd,de->bse", xc.astype(h.dtype), bp["m_xbc"])
    Bm = bcd[..., :N].astype(jnp.float32)              # (B,S,N)
    Cm = bcd[..., N:2 * N].astype(jnp.float32)
    dt_raw = bcd[..., 2 * N:].astype(jnp.float32)      # (B,S,d)
    delta = jax.nn.softplus(dt_raw + bp["m_dt_bias"])
    A = -jnp.exp(bp["m_alog"])                         # (d, N)

    def step(state, inp):
        x_t, B_t, C_t, dl_t = inp                      # (B,d),(B,N),(B,N),(B,d)
        dA = jnp.exp(dl_t[..., None] * A)              # (B,d,N)
        dBx = dl_t[..., None] * B_t[:, None, :] * x_t[..., None]
        state = state * dA + dBx
        y = jnp.einsum("bdn,bn->bd", state, C_t)
        return state, y

    if ssm_state is None:
        ssm_state = jnp.zeros((B, d, N), jnp.float32)
    ssm_state, ys = chunked_scan(
        step, ssm_state,
        (xc.swapaxes(0, 1), Bm.swapaxes(0, 1), Cm.swapaxes(0, 1),
         delta.swapaxes(0, 1)), cfg.ssm_chunk)
    y = ys.swapaxes(0, 1) + xc * bp["m_d"]
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = jnp.einsum("bsd,de->bse", y.astype(h.dtype), bp["m_out"])
    return out, new_conv_state, ssm_state


def _block(cfg, bp, x, positions, kv=None, pos=None):
    """Parallel attn + mamba. kv/pos given -> decode mode (S==1)."""
    B, S, d = x.shape
    h = rms_norm(x, bp["ln1"], cfg.norm_eps)
    q, k, v = _project_qkv(cfg, bp, h, positions)
    if kv is None:
        a_out = attn.attention(q, k, v, causal=True,
                               window=cfg.hymba_window)
        new_kv = (k, v)
    else:
        kc, vc, slot, w = kv
        kc = lax.dynamic_update_slice_in_dim(kc, k, slot, axis=1)
        vc = lax.dynamic_update_slice_in_dim(vc, v, slot, axis=1)
        ages = (slot - jnp.arange(w)) % w
        abs_idx = pos - ages
        s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                       attn._expand_kv(kc, cfg.n_heads)
                       .astype(jnp.float32)) / jnp.sqrt(cfg.hd)
        ok = (abs_idx >= 0) & (abs_idx <= pos) & (abs_idx > pos - w)
        s = jnp.where(ok[None, None, None], s, attn.NEG_INF)
        p = jax.nn.softmax(s, -1)
        a_out = jnp.einsum("bhqk,bkhd->bqhd", p,
                           attn._expand_kv(vc, cfg.n_heads)
                           .astype(jnp.float32)).astype(q.dtype)
        new_kv = (kc, vc)
    a_out = jnp.einsum("bsh,hd->bsd", a_out.reshape(B, S, -1), bp["wo"])
    a_out = rms_norm(a_out, bp["attn_norm"], cfg.norm_eps)
    return h, a_out, new_kv


def forward(cfg: ModelConfig, params, tokens, positions=None,
            prefix_embeds=None) -> jax.Array:
    x = embed_tokens(params["embed"], tokens,
                     jnp.dtype(cfg.compute_dtype))
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def body(carry, bp):
        from repro.models.shardctx import constrain_batch
        x = constrain_batch(carry)
        h, a_out, _ = _block(cfg, bp, x, positions)
        m_out, _, _ = _mamba_scan(cfg, bp, h)
        m_out = rms_norm(m_out, bp["mamba_norm"], cfg.norm_eps)
        x = x + 0.5 * (a_out + m_out)
        h2 = rms_norm(x, bp["ln2"], cfg.norm_eps)
        x = x + swiglu(h2, bp["w_gate"], bp["w_up"], bp["w_down"])
        return x, None

    x, _ = lax.scan(body, x, params["blocks"])
    return rms_norm(x, params["final_norm"], cfg.norm_eps)


def loss_fn(cfg: ModelConfig, params, batch) -> jax.Array:
    h = forward(cfg, params, batch["tokens"])
    return chunked_softmax_xent(h, params["lm_head"], batch["labels"],
                                chunk=cfg.logits_chunk)


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    L, d, N = cfg.n_layers, cfg.d_model, cfg.ssm_state
    KV, hd = cfg.n_kv_heads, cfg.hd
    w = cfg.hymba_window
    dt = jnp.dtype(cfg.compute_dtype)
    return {
        "k": jnp.zeros((L, batch, w, KV, hd), dt),
        "v": jnp.zeros((L, batch, w, KV, hd), dt),
        "conv": jnp.zeros((L, batch, 3, d), dt),
        "ssm": jnp.zeros((L, batch, d, N), jnp.float32),
    }


def decode_step(cfg: ModelConfig, params, cache, tokens, pos):
    B = tokens.shape[0]
    x = embed_tokens(params["embed"], tokens,
                     jnp.dtype(cfg.compute_dtype))
    w = cache["k"].shape[2]
    positions = jnp.broadcast_to(pos, (B, 1))
    slot = pos % w

    def body(carry, inp):
        x = carry
        bp, kc, vc, conv, ssm = inp
        h, a_out, (kc, vc) = _block(cfg, bp, x, positions,
                                    kv=(kc, vc, slot, w), pos=pos)
        m_out, conv, ssm = _mamba_scan(cfg, bp, h, conv_state=conv,
                                       ssm_state=ssm)
        m_out = rms_norm(m_out, bp["mamba_norm"], cfg.norm_eps)
        x = x + 0.5 * (a_out + m_out)
        h2 = rms_norm(x, bp["ln2"], cfg.norm_eps)
        x = x + swiglu(h2, bp["w_gate"], bp["w_up"], bp["w_down"])
        return x, (kc, vc, conv, ssm)

    x, (kc, vc, conv, ssm) = lax.scan(
        body, x, (params["blocks"], cache["k"], cache["v"],
                  cache["conv"], cache["ssm"]))
    cache = {"k": kc, "v": vc, "conv": conv, "ssm": ssm}
    x = rms_norm(x[:, 0], params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", x.astype(jnp.float32),
                        params["lm_head"].astype(jnp.float32))
    return logits, cache


def prefill(cfg: ModelConfig, params, tokens):
    B, S = tokens.shape
    x = embed_tokens(params["embed"], tokens,
                     jnp.dtype(cfg.compute_dtype))
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    w = cfg.hymba_window
    keep = min(S, w)
    slots = (jnp.arange(S - keep, S) % w)   # ring slots of the kept tail

    def body(carry, bp):
        x = carry
        h, a_out, (k, v) = _block(cfg, bp, x, positions)
        m_out, conv, ssm = _mamba_scan(cfg, bp, h)
        m_out = rms_norm(m_out, bp["mamba_norm"], cfg.norm_eps)
        x = x + 0.5 * (a_out + m_out)
        h2 = rms_norm(x, bp["ln2"], cfg.norm_eps)
        x = x + swiglu(h2, bp["w_gate"], bp["w_up"], bp["w_down"])
        kc = jnp.zeros((B, w) + k.shape[2:], k.dtype) \
            .at[:, slots].set(k[:, -keep:])
        vc = jnp.zeros((B, w) + v.shape[2:], v.dtype) \
            .at[:, slots].set(v[:, -keep:])
        return x, (kc, vc, conv, ssm)

    x, (kc, vc, conv, ssm) = lax.scan(body, x, params["blocks"])
    cache = {"k": kc, "v": vc, "conv": conv, "ssm": ssm}
    x = rms_norm(x[:, -1], params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", x.astype(jnp.float32),
                        params["lm_head"].astype(jnp.float32))
    return logits, cache
