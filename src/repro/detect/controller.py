"""Re-planning controller policies over an estimated failure timeline.

The detector (`repro.detect.detector`) turns the true `FaultTimeline` into
an estimated one; the policies here decide which estimated changes are
worth acting on. `planner.replay` consumes the policy-filtered timeline as
its re-plan triggers while still simulating every plan against the truth:

  immediate  act on every estimated breakpoint (the PR-8 oracle behavior,
             now fed by a possibly-wrong estimate);
  debounce   require the estimated state to persist K consecutive probes
             before confirming it - a one-probe FP blip or a sub-cadence
             NIC flap never confirms, at the price of (K-1) probe
             intervals of extra reaction lag on real changes;
  backoff    act immediately but enforce an exponentially growing minimum
             spacing between successive re-plans (2x after each), bounding
             re-plan churn under sustained flapping. The spacing floor is
             applied inside `planner.replay` (it depends on when re-plans
             actually land); `apply_policy` passes the timeline through.

All policies degrade gracefully to the oracle under a perfect detector:
debounce with continuous observation (probe_interval == 0) has a zero-width
confirmation window and backoff with base 0 has no floor, so the acceptance
bit-identity (perfect detector + any zero-parameter policy == PR 8) holds.
"""
from __future__ import annotations

import dataclasses

from repro.core.model import BandwidthProfile, FaultEvent, FaultTimeline
from repro.detect.detector import DetectionResult, true_changes

__all__ = ["MAX_CREDIBLE_ELL", "POLICIES", "ControllerConfig",
           "apply_policy", "debounce_timeline", "estimate_usable"]

POLICIES = ("immediate", "debounce", "backoff")

# An estimate claiming (almost) every NIC is degraded, or absurd severity,
# says more about the detector than the fabric: planning OptCC for it would
# pick a straggler set with no healthy helpers left. `planner.replay` then
# falls back to the degraded FIFO ring, which is valid under any profile.
MAX_CREDIBLE_ELL = 64.0


@dataclasses.dataclass(frozen=True)
class ControllerConfig:
    """Which policy filters the estimated timeline into re-plan triggers.

    debounce_probes: K - an estimated change must survive K consecutive
      probes (i.e. (K-1) probe intervals with no contrary estimate) before
      it confirms; K=1 degenerates to immediate.
    backoff_base: minimum spacing (element-time) between re-plan i and i+1,
      doubled after every re-plan; <= 0 auto-derives 4 probe intervals.
    """

    policy: str = "immediate"
    debounce_probes: int = 3
    backoff_base: float = 0.0

    def __post_init__(self):
        if self.policy not in POLICIES:
            raise ValueError(f"unknown policy {self.policy!r}; "
                             f"choose from {POLICIES}")
        if self.debounce_probes < 1:
            raise ValueError("debounce_probes must be >= 1")

    def backoff_spacing(self, probe_interval: float, replans: int) -> float:
        """Minimum time until the next re-plan after the `replans`-th one."""
        base = self.backoff_base if self.backoff_base > 0 \
            else 4.0 * probe_interval
        return base * (2.0 ** max(replans - 1, 0))


def estimate_usable(profile: BandwidthProfile) -> bool:
    """Is an estimated profile credible enough to plan OptCC for? See
    MAX_CREDIBLE_ELL; `planner.replay` forces the ring fallback otherwise."""
    stragglers = profile.stragglers
    if len(stragglers) >= profile.p - 1:
        return False
    return max(profile.slowdown) <= MAX_CREDIBLE_ELL


def debounce_timeline(timeline: FaultTimeline, profile: BandwidthProfile,
                      probe_interval: float, k: int
                      ) -> tuple[FaultTimeline, int]:
    """Confirm estimated changes that persist K consecutive probes.

    An effective change at estimated time t confirms at ``t + (k-1)*dt``
    unless a contrary estimate lands on the same rank inside that window -
    then *both* are suppressed (the state never stabilized; re-planning for
    either side of a flap is thrash). Returns (confirmed timeline,
    suppressed change count). k=1 or dt=0 is the identity.
    """
    if k <= 1 or probe_interval <= 0.0:
        return timeline, 0
    window = (k - 1) * probe_interval
    # Probe times are i*dt floats; comparing j2*dt <= (j1+k-1)*dt must not
    # hinge on float rounding of the products.
    eps = 1e-9 * probe_interval
    changes = true_changes(profile, timeline)
    events: list[FaultEvent] = []
    suppressed = 0
    for r in sorted(changes):
        chs = changes[r]
        for i, (t, v) in enumerate(chs):
            nxt = chs[i + 1][0] if i + 1 < len(chs) else None
            if nxt is not None and nxt <= t + window + eps:
                suppressed += 1
                continue
            events.append(FaultEvent(t + window, r, v))
    return FaultTimeline(tuple(events)), suppressed


def apply_policy(detection: DetectionResult, profile: BandwidthProfile,
                 config: ControllerConfig) -> tuple[FaultTimeline, int]:
    """Filter an estimate into the trigger timeline `planner.replay` walks.
    Returns (trigger timeline, suppressed estimated changes)."""
    if config.policy == "debounce":
        return debounce_timeline(detection.timeline, profile,
                                 detection.config.probe_interval,
                                 config.debounce_probes)
    return detection.timeline, 0
