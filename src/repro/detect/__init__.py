"""Imperfect fault detection + controller policies.

The layer between ground truth (`core.model.FaultTimeline`) and reaction
(`core.planner.replay`): a probe-based detector that observes the true
timeline through a configurable lens (latency, probe cadence, noise,
quantization, FP/FN rates) and controller policies (immediate / debounce /
backoff) that decide which estimated changes trigger a re-plan. Plans are
generated from the *estimate* but always simulated against the *truth* -
mis-plan-tolerant execution - so the sweep's `detection` family can score
real controller policies against the PR-8 zero-delay oracle
(`overhead_vs_oracle`).

Public API:
  DetectorConfig, DetectionResult, estimate_timeline   - the lens
  ControllerConfig, POLICIES, apply_policy,
  debounce_timeline, estimate_usable                   - the policies
"""
from repro.detect.controller import (MAX_CREDIBLE_ELL, POLICIES,
                                     ControllerConfig, apply_policy,
                                     debounce_timeline, estimate_usable)
from repro.detect.detector import (DetectionResult, DetectorConfig,
                                   estimate_timeline, true_changes)

__all__ = [
    "DetectorConfig", "DetectionResult", "estimate_timeline", "true_changes",
    "ControllerConfig", "POLICIES", "MAX_CREDIBLE_ELL", "apply_policy",
    "debounce_timeline", "estimate_usable",
]
