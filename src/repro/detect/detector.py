"""Probe-based fault-detection model: the imperfect lens between the true
failure timeline and the re-planning controller.

`planner.replay`'s PR-8 controller is an oracle: it reacts to every rate
change with zero delay and perfect knowledge of the new bandwidth vector.
Real collective libraries see degradation through periodic health probes
(NIC counters, RDMA CM events, in-band RTT probes) that lag, quantize and
occasionally lie - R2CCL builds its recovery path around explicit bounded-
latency detection, and the observable-CCL work shows detection/attribution
latency dominating real recovery times (PAPERS.md). This module models that
lens: it observes a ground-truth `FaultTimeline` and emits an *estimated*
timeline that lags and distorts it.

The detector samples per-rank NIC state at probe ticks ``i * probe_interval``
(i >= 1); a probe at time ``t`` sees the state as of ``t - latency``
(sensing/aggregation delay). When the sampled state differs from the last
value the detector reported for that rank, it reports the change - unless a
per-probe false-negative coin says the probe missed it, in which case the
next probe retries (geometric extra lag). Reported slowdowns are distorted
multiplicatively on the degradation magnitude (``1 + (ell-1) * e^{N(0,
noise)}`` - a recovery to 1.0 is always reported as exactly 1.0) and then
quantized to a grid of ``quant`` (telemetry counters have finite
resolution). Independently, each probe tick may fire a false positive: a
spurious degradation on a currently-healthy rank that clears at the next
probe (the one-probe blip the debounce policy exists to suppress).

``probe_interval == 0`` means continuous observation: changes are reported
``latency`` after they happen (exactly on time for ``latency == 0``), and
the per-probe FP/FN machinery is unavailable. `DetectorConfig.perfect()` is
the fully transparent lens: the estimated timeline reproduces the truth
event-for-event with identical floats, which is what keeps oracle-mode
`planner.replay` bit-identical (tests/test_detect.py pins this on every
checked-in ci/traces file).

All times are element-time units (the simulator clock). Randomness comes
from stream-split `random.Random` instances seeded from ``config.seed``, so
an estimate is a pure function of (profile, timeline, horizon, config).
"""
from __future__ import annotations

import dataclasses
import random
from typing import Optional

from repro.core.model import BandwidthProfile, FaultEvent, FaultTimeline

__all__ = ["DetectorConfig", "DetectionResult", "estimate_timeline",
           "true_changes"]


@dataclasses.dataclass(frozen=True)
class DetectorConfig:
    """How imperfectly the runtime sees the fabric.

    probe_interval: element-time between health probes; 0 = continuous
      observation (no probes, no FP/FN).
    latency: fixed sensing delay - a probe at t sees the state of
      t - latency; with probe_interval == 0, changes surface latency late.
    noise: sigma of the multiplicative lognormal distortion applied to the
      degradation magnitude (ell - 1) of reported slowdowns.
    quant: reported ell values are snapped to 1 + m * quant (m integer,
      nearest); 0 disables quantization.
    fp_rate: per-probe probability of a spurious one-probe degradation blip
      on a random currently-healthy rank.
    fn_rate: per-probe probability that a probe misses a pending change
      (the next probe retries).
    fp_ell: severity reported by false-positive blips.
    seed: RNG seed; estimates are deterministic given (inputs, seed).
    """

    probe_interval: float = 0.0
    latency: float = 0.0
    noise: float = 0.0
    quant: float = 0.0
    fp_rate: float = 0.0
    fn_rate: float = 0.0
    fp_ell: float = 2.0
    seed: int = 0

    def __post_init__(self):
        if self.probe_interval < 0 or self.latency < 0:
            raise ValueError("probe_interval and latency must be >= 0")
        if self.noise < 0 or self.quant < 0:
            raise ValueError("noise and quant must be >= 0")
        if not (0.0 <= self.fp_rate < 1.0 and 0.0 <= self.fn_rate < 1.0):
            raise ValueError("fp_rate and fn_rate must be in [0, 1)")
        if self.fp_ell < 1.0:
            raise ValueError("fp_ell must be >= 1")
        if self.probe_interval == 0.0 and (self.fp_rate or self.fn_rate):
            raise ValueError("false positives/negatives need discrete "
                             "probes (probe_interval > 0)")

    @property
    def is_perfect(self) -> bool:
        """A fully transparent lens: the estimate equals the truth."""
        return (self.probe_interval == 0.0 and self.latency == 0.0
                and self.noise == 0.0 and self.quant == 0.0
                and self.fp_rate == 0.0 and self.fn_rate == 0.0)

    @classmethod
    def perfect(cls) -> "DetectorConfig":
        return cls()

    @classmethod
    def default(cls, scale: float = 1.0, seed: int = 0) -> "DetectorConfig":
        """The default *imperfect* detector: probes every 0.04 time-scales
        (pass the scenario's fault-free optimum T0 as `scale` so the lens
        degrades proportionally at every cluster size), 0.01-scale sensing
        latency, 15% multiplicative noise, quarter-step ell quantization,
        2% FP and 5% FN per probe."""
        return cls(probe_interval=0.04 * scale, latency=0.01 * scale,
                   noise=0.15, quant=0.25, fp_rate=0.02, fn_rate=0.05,
                   seed=seed)


@dataclasses.dataclass(frozen=True)
class DetectionResult:
    """An estimated timeline plus how the lens performed against the truth.

    lags: detection lag (report time - true change time) per reported true
      change, in element-time. missed: true changes never reported within
      the horizon (superseded between probes, or quantized/FN'd away).
    false_events: spurious FP events injected (blip + clear pairs count 1).
    """

    timeline: FaultTimeline
    config: DetectorConfig
    probes: int
    lags: tuple[float, ...]
    missed: int
    false_events: int

    @property
    def lag_mean(self) -> Optional[float]:
        return sum(self.lags) / len(self.lags) if self.lags else None

    @property
    def lag_max(self) -> Optional[float]:
        return max(self.lags) if self.lags else None


def true_changes(profile: BandwidthProfile, timeline: FaultTimeline
                 ) -> dict[int, list[tuple[float, float]]]:
    """Per-rank effective value changes after t=0: {rank: [(t, new_ell),
    ...]}. Thin alias over `FaultTimeline.changes` kept as the detect-layer
    entry point (the detector samples this view through its probe lens)."""
    return timeline.changes(profile)


def _distort(ell: float, config: DetectorConfig,
             rng: random.Random) -> float:
    """Noise + quantization of a reported slowdown. Recoveries pass through
    exactly (a link that is back is unambiguous; what is noisy is *how
    degraded* a degraded link is)."""
    if ell <= 1.0:
        return 1.0
    est = ell
    if config.noise > 0.0:
        est = 1.0 + (ell - 1.0) * rng.lognormvariate(0.0, config.noise)
    if config.quant > 0.0:
        est = 1.0 + round((est - 1.0) / config.quant) * config.quant
    return max(1.0, est)


def _value_at(changes: list[tuple[float, float]], base: float,
              t: float) -> float:
    """True value of a rank at time t given its change list (t<0 -> base)."""
    v = base
    for ct, cv in changes:
        if ct > t:
            break
        v = cv
    return v


def estimate_timeline(profile: BandwidthProfile, timeline: FaultTimeline,
                      horizon: float, config: DetectorConfig
                      ) -> DetectionResult:
    """Observe `timeline` (resolved against `profile`) through the lens of
    `config` up to `horizon`: returns the estimated timeline the controller
    will re-plan from, plus lag/miss/FP statistics.

    The launch profile itself (t=0 state) is assumed known exactly - the
    runtime measured it when it planned - so estimation concerns mid-flight
    changes only, mirroring `planner.replay`'s t<=0 folding.
    """
    if horizon < 0:
        raise ValueError("horizon must be >= 0")
    changes = true_changes(profile, timeline)
    if config.is_perfect:
        events = tuple(FaultEvent(t, r, v)
                       for r in sorted(changes)
                       for t, v in changes[r])
        return DetectionResult(timeline=FaultTimeline(events), config=config,
                               probes=0,
                               lags=(0.0,) * len(events), missed=0,
                               false_events=0)

    rng_noise = random.Random(f"{config.seed}:noise")
    rng_fn = random.Random(f"{config.seed}:fn")
    rng_fp = random.Random(f"{config.seed}:fp")
    events: list[FaultEvent] = []
    lags: list[float] = []
    reported_total = 0
    total_changes = sum(len(c) for c in changes.values())

    if config.probe_interval == 0.0:
        # Continuous observation: every change surfaces `latency` late with
        # a distorted value; nothing can be missed or invented.
        for r in sorted(changes):
            for t, v in changes[r]:
                if t + config.latency > horizon:
                    continue
                events.append(FaultEvent(t + config.latency, r,
                                         _distort(v, config, rng_noise)))
                lags.append(config.latency)
                reported_total += 1
        return DetectionResult(timeline=FaultTimeline(tuple(events)),
                               config=config, probes=0, lags=tuple(lags),
                               missed=total_changes - reported_total,
                               false_events=0)

    dt = config.probe_interval
    nprobes = int(horizon / dt)
    probe_times = [i * dt for i in range(1, nprobes + 1)]
    # Per-rank state sampling: a probe reports iff the (lagged) true value
    # differs from the last value this detector reported for the rank.
    # Changes that flap faster than the probe cadence are superseded
    # unseen - exactly the blindness a debounce policy trades lag for.
    for r in sorted(changes):
        base_v = profile.slowdown[r]
        last_seen = base_v
        for pt in probe_times:
            v = _value_at(changes[r], base_v, pt - config.latency)
            if v == last_seen:
                continue
            if config.fn_rate and rng_fn.random() < config.fn_rate:
                continue                      # missed; next probe retries
            events.append(FaultEvent(pt, r, _distort(v, config, rng_noise)))
            # Lag is measured against the change that set the sampled value.
            ct = max(t for t, cv in changes[r] if t <= pt - config.latency)
            lags.append(pt - ct)
            reported_total += 1
            last_seen = v
    # False positives: one-probe blips on currently-healthy ranks.
    false_events = 0
    for pt in probe_times:
        if not config.fp_rate or rng_fp.random() >= config.fp_rate:
            continue
        healthy = [r for r in range(profile.p)
                   if _value_at(changes.get(r, []), profile.slowdown[r],
                                pt) <= 1.0]
        if not healthy:
            continue
        r = healthy[rng_fp.randrange(len(healthy))]
        events.append(FaultEvent(pt, r, config.fp_ell))
        events.append(FaultEvent(pt + dt, r, 1.0))
        false_events += 1
    return DetectionResult(timeline=FaultTimeline(tuple(events)),
                           config=config, probes=nprobes, lags=tuple(lags),
                           missed=total_changes - reported_total,
                           false_events=false_events)
