"""Serving: prefill + greedy decode loops and dry-run serve_step builders.

`serve_step` is the unit the decode_* / long_* dry-run cells lower: one new
token given a KV cache (or recurrent state) of the cell's seq_len.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.api import Model


def make_serve_step(model: Model):
    """Returns step(params, cache, token, pos) -> (next_token, cache)."""
    def step(params, cache, token, pos):
        logits, cache = model.decode_step(params, cache, token, pos)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return nxt, cache
    return step


FULL_SEQ_CACHE_KEYS = ("k_glob", "v_glob")


def pad_cache_to(cache: dict, target_len: int, keys=None) -> dict:
    """Grow *full-sequence* caches (length S) to a decode budget.

    Only the named keys are padded: window caches (hymba, gemma3 local),
    recurrent states (rwkv/mamba) and cross-attention caches must NOT
    grow. Whisper's self cache lives under "k"/"v" - pass those.
    """
    keys = FULL_SEQ_CACHE_KEYS if keys is None else keys
    out = dict(cache)
    for key in keys:
        if key in out:
            x = out[key]
            if x.ndim == 5 and x.shape[2] < target_len:
                pad = [(0, 0)] * x.ndim
                pad[2] = (0, target_len - x.shape[2])
                out[key] = jnp.pad(x, pad)
    return out


def generate(model: Model, params, prompt: jax.Array, max_new: int,
             batch_extras: Optional[dict] = None) -> np.ndarray:
    """Greedy generation: prefill the prompt then decode max_new tokens."""
    B, S = prompt.shape
    pb = {"tokens": prompt}
    if batch_extras:
        pb.update(batch_extras)
    logits, cache = jax.jit(model.prefill)(params, pb)
    pad_keys = ("k", "v") if model.cfg.family == "whisper" else None
    cache = pad_cache_to(cache, S + max_new, keys=pad_keys)
    step = jax.jit(make_serve_step(model))
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    out = [tok]
    pos = S
    for i in range(max_new - 1):
        tok, cache = step(params, cache, tok, jnp.int32(pos))
        out.append(tok)
        pos += 1
    return np.concatenate([np.asarray(t) for t in out], axis=1)
