from repro.train.state import TrainState
from repro.train.step import (init_train_state, make_dp_failover_step,
                              make_gspmd_train_step, shardings_for_params)

__all__ = ["TrainState", "init_train_state", "make_gspmd_train_step",
           "make_dp_failover_step", "shardings_for_params"]
