"""TrainState pytree."""
from __future__ import annotations

import jax


@jax.tree_util.register_pytree_node_class
class TrainState:
    def __init__(self, params, opt_state, step):
        self.params = params
        self.opt_state = opt_state
        self.step = step

    def tree_flatten(self):
        return (self.params, self.opt_state, self.step), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    def replace(self, **kw):
        d = {"params": self.params, "opt_state": self.opt_state,
             "step": self.step}
        d.update(kw)
        return TrainState(**d)
