"""Train-step factories.

Two distribution modes:

* make_gspmd_train_step - the production path: jit + GSPMD over the
  (pod, data, model) mesh. Batch is sharded over pod x data, parameters
  over model (tensor parallel) and optionally data (FSDP); XLA emits the
  gradient reduce-scatters / all-gathers. This is the path the multi-pod
  dry-run lowers and the roofline reads. Optional microbatch gradient
  accumulation (scan) overlaps per-microbatch sync with the next
  microbatch's compute.

* make_dp_failover_step - the fault-tolerant data-parallel path:
  shard_map over a 1-D DP mesh with parameters replicated; gradients are
  produced per-shard and synchronized by an *explicit software collective*
  selected from the live FaultState: XLA psum when healthy,
  comms.optcc_allreduce when a member's link is degraded (the paper's
  algorithm), optionally int8-compressed. At production scale each
  tensor-parallel rank group runs exactly this program over its DP peers
  (see DESIGN.md "Stage mapping").
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.comms import optcc_allreduce_tree
from repro.comms.fault import FaultState
from repro.models.api import Model
from repro.optim import AdamWConfig, init_state, update
from repro.train.state import TrainState


# ----------------------------------------------------------------------------
# GSPMD production path
# ----------------------------------------------------------------------------

def batch_spec(mesh: Mesh) -> P:
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return P(axes if len(axes) > 1 else axes[0])


def param_pspec(path: str, leaf, cfg, mesh: Mesh) -> P:
    """Sharding rule for one parameter leaf.

    TP: last (output-features) dim over 'model' for up-projections,
    first over 'model' for down-projections; embeddings/vocab over
    'model'. FSDP: additionally shard the largest remaining dim over
    'data' when cfg.fsdp (plus 'pod' for very large tensors).
    """
    shape = leaf.shape
    name = path.split("/")[-1]
    ndim = len(shape)
    spec: list = [None] * ndim
    model_dim = None
    if name in ("embed", "lm_head", "pos_embed"):
        # (V, d) / (d, V): shard vocab over model
        model_dim = 0 if name == "embed" else ndim - 1
    elif name in ("wq", "wk", "wv", "w_gate", "w_up", "xq", "xk", "xv",
                  "m_in", "m_xbc", "ck", "cr", "wr", "wk", "wv", "wg",
                  "e_gate", "e_up", "d_gate", "d_up"):
        model_dim = ndim - 1          # output features
    elif name in ("wo", "w_down", "xo", "m_out", "cv", "e_down", "d_down"):
        model_dim = ndim - 2 if ndim >= 2 else None  # input features
    elif name == "router":
        model_dim = None              # small, replicated
    if name in ("e_gate", "e_up", "e_down"):
        n_exp = getattr(cfg, "n_experts", 0) if cfg is not None else 0
        if n_exp >= 64:
            # expert parallelism: experts over model (arctic: 128e).
            spec[1 if ndim == 4 else 0] = "model"
            model_dim = None
        else:
            # TP inside experts: shard the FFN hidden dim over model so
            # the dispatch scatter/gather stays device-local (phi3.5:
            # 16e; EP via GSPMD scatter costs an all-reduce of the full
            # dispatch buffer per layer - measured in SPerf).
            model_dim = ndim - 1 if name in ("e_gate", "e_up") \
                else ndim - 2
    if model_dim is not None and shape[model_dim] % mesh.shape["model"] == 0:
        spec[model_dim] = "model"
    # FSDP (ZeRO-3 style): shard the largest remaining dim over data.
    # Embedding-like tables are excluded: sharding their feature dim over
    # data forces GSPMD into full rematerialization around the token
    # gather (the vocab dim is already sharded over model).
    if cfg is not None and getattr(cfg, "fsdp", False) \
            and name not in ("embed", "lm_head", "pos_embed"):
        free = [i for i in range(ndim) if spec[i] is None]
        if free:
            i = max(free, key=lambda i: shape[i])
            if shape[i] % mesh.shape["data"] == 0 and shape[i] >= 1024:
                spec[i] = "data"
    return P(*spec)


def shardings_for_params(params, cfg, mesh: Mesh):
    def one(path, leaf):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        return NamedSharding(mesh, param_pspec(key, leaf, cfg, mesh))
    return jax.tree_util.tree_map_with_path(one, params)


def make_gspmd_train_step(model: Model, mesh: Mesh,
                          opt_cfg: AdamWConfig,
                          lr_fn: Callable,
                          num_microbatches: int = 1,
                          donate: bool = True):
    cfg = model.cfg
    bspec = batch_spec(mesh)

    def loss_fn(params, batch):
        return model.loss(params, batch)

    def step(state: TrainState, batch: dict):
        if num_microbatches > 1:
            from repro.models.shardctx import constrain_batch
            def micro(carry, mb):
                gacc, lacc = carry
                mb = jax.tree.map(
                    lambda a: constrain_batch(a) if a.ndim >= 2 else a, mb)
                l, g = jax.value_and_grad(loss_fn)(state.params, mb)
                return (jax.tree.map(jnp.add, gacc, g), lacc + l), None
            mbs = jax.tree.map(
                lambda x: x.reshape((num_microbatches,
                                     x.shape[0] // num_microbatches)
                                    + x.shape[1:]), batch)
            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            try:   # keep the grad accumulator sharded like the params
                pshard = shardings_for_params(state.params, cfg, mesh)
                zero = jax.tree.map(jax.lax.with_sharding_constraint,
                                    zero, pshard)
            except Exception:
                pass
            (grads, loss), _ = lax.scan(micro, (zero, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / num_microbatches, grads)
            loss = loss / num_microbatches
        else:
            loss, grads = jax.value_and_grad(loss_fn)(state.params, batch)
        lr = lr_fn(state.step)
        new_params, new_opt, gnorm = update(state.params, grads,
                                            state.opt_state, lr, opt_cfg)
        return (TrainState(new_params, new_opt, state.step + 1),
                {"loss": loss, "grad_norm": gnorm, "lr": lr})

    return step


# ----------------------------------------------------------------------------
# fault-tolerant pure-DP path (shard_map + explicit sync)
# ----------------------------------------------------------------------------

def make_dp_failover_step(model: Model, mesh: Mesh,
                          opt_cfg: AdamWConfig, lr_fn: Callable,
                          fault: FaultState,
                          compression: bool = False):
    """shard_map train step over a 1-D ('data',) mesh.

    Gradient sync: psum when fault.healthy, optcc_allreduce when degraded.
    Re-call this factory (re-jit) whenever `fault` changes - that is the
    NCCL-reinit analogue; the OptCC planner's closed form makes the new
    schedule cheap to produce.
    """
    assert mesh.axis_names == ("data",)
    dp = mesh.shape["data"]

    def body(params, opt_state, step_no, batch):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        if fault.degraded:
            grads = optcc_allreduce_tree(grads, "data",
                                         fault.straggler, dp)
            grads = jax.tree.map(lambda g: g / dp, grads)
            loss = lax.psum(loss, "data") / dp
        else:
            grads = jax.tree.map(lambda g: lax.psum(g, "data") / dp,
                                 grads)
            loss = lax.psum(loss, "data") / dp
        lr = lr_fn(step_no)
        new_params, new_opt, gnorm = update(params, grads, opt_state, lr,
                                            opt_cfg)
        return new_params, new_opt, loss, gnorm

    smapped = jax.shard_map(
        body, mesh=mesh,
        in_specs=(P(), P(), P(), P("data")),
        out_specs=(P(), P(), P(), P()),
        check_vma=False)

    @jax.jit
    def step(state: TrainState, batch: dict):
        new_params, new_opt, loss, gnorm = smapped(
            state.params, state.opt_state, state.step, batch)
        return (TrainState(new_params, new_opt, state.step + 1),
                {"loss": loss, "grad_norm": gnorm})

    return step


def init_train_state(model: Model, opt_cfg: AdamWConfig, seed: int = 0
                     ) -> TrainState:
    params = jax.jit(model.init)(jax.random.PRNGKey(seed))
    return TrainState(params, init_state(params, opt_cfg),
                      jnp.zeros((), jnp.int32))
