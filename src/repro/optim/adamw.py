"""AdamW with configurable moment dtype (HBM knob for 480B-class models)
and global-norm clipping. Pure-pytree implementation (no optax dependency).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"


def init_state(params, cfg: AdamWConfig) -> dict:
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)  # noqa: E731
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale)
                        .astype(g.dtype), grads), norm


def update(params, grads, state, lr, cfg: AdamWConfig):
    """Returns (new_params, new_state, grad_norm)."""
    if cfg.clip_norm > 0:
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    else:
        gnorm = global_norm(grads)
    count = state["count"] + 1
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, mu, nu):
        gf = g.astype(jnp.float32)
        mu_n = cfg.b1 * mu.astype(jnp.float32) + (1 - cfg.b1) * gf
        nu_n = cfg.b2 * nu.astype(jnp.float32) + (1 - cfg.b2) * gf * gf
        step = (mu_n / b1c) / (jnp.sqrt(nu_n / b2c) + cfg.eps)
        pf = p.astype(jnp.float32)
        pf = pf - lr * (step + cfg.weight_decay * pf)
        return pf.astype(p.dtype), mu_n.astype(mdt), nu_n.astype(mdt)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state["mu"])
    flat_nu = jax.tree.leaves(state["nu"])
    out = [upd(p, g, m, n)
           for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_mu = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_nu = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, {"mu": new_mu, "nu": new_nu, "count": count}, gnorm
