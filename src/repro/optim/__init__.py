from repro.optim.adamw import (AdamWConfig, clip_by_global_norm,
                               global_norm, init_state, update)
from repro.optim.schedules import constant, cosine, warmup_stable_decay

__all__ = ["AdamWConfig", "init_state", "update", "global_norm",
           "clip_by_global_norm", "warmup_stable_decay", "cosine",
           "constant"]
