"""LR schedules: WSD (Warmup-Stable-Decay, MiniCPM arXiv:2404.06395),
cosine, and linear warmup helpers. All are step -> lr callables usable
under jit (jnp arithmetic only).
"""
from __future__ import annotations

import jax.numpy as jnp


def warmup_stable_decay(peak_lr: float, warmup: int, stable: int,
                        decay: int, final_frac: float = 0.1):
    """MiniCPM's WSD: linear warmup, long stable plateau, short decay."""
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        w = peak_lr * jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
        in_decay = step > (warmup + stable)
        t = jnp.clip((step - warmup - stable) / jnp.maximum(decay, 1),
                     0.0, 1.0)
        decayed = peak_lr * (final_frac ** t)
        return jnp.where(in_decay, decayed, w)
    return lr


def cosine(peak_lr: float, warmup: int, total: int,
           final_frac: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        w = peak_lr * jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
        t = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1),
                     0.0, 1.0)
        c = peak_lr * (final_frac + (1 - final_frac)
                       * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup, w, c)
    return lr


def constant(lr_value: float):
    def lr(step):
        return jnp.full((), lr_value, jnp.float32)
    return lr
