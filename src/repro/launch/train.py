"""End-to-end training driver with fault tolerance.

Runs data-parallel training with:
  * checkpoint/restart (atomic, auto-resume from the latest step),
  * deterministic failure injection (NIC degradation events) -> on each
    event the OptCC planner produces the new collective schedule and the
    train step is re-built (re-jit), mirroring NCCL communicator re-init,
  * straggler mitigation = the paper's algorithm (degraded mode syncs
    gradients with optcc_allreduce instead of psum).

Works on any device count >= 1 (the DP axis is however many devices jax
sees; force more with XLA_FLAGS=--xla_force_host_platform_device_count=8).

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --smoke \
      --steps 200 --fail-at 60 --repair-at 120 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.checkpoint import latest_step, restore, save
from repro.comms.fault import FailureInjector, FaultState
from repro.configs import get_config
from repro.data import DataConfig, SyntheticLM
from repro.models import build_model
from repro.optim import AdamWConfig
from repro.optim.schedules import warmup_stable_decay
from repro.train import init_train_state, make_dp_failover_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject NIC degradation at this step")
    ap.add_argument("--repair-at", type=int, default=None)
    ap.add_argument("--ell", type=float, default=1.5,
                    help="slowdown factor of the injected degradation")
    ap.add_argument("--straggler", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--lose-node-at", type=int, default=None,
                    help="simulate losing half the DP members at this "
                         "step: checkpoint, rebuild the mesh on the "
                         "survivors, restore, continue (elastic rescale)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    dp = jax.device_count()
    mesh = Mesh(np.array(jax.devices()), ("data",))
    opt = AdamWConfig(weight_decay=0.01)
    lr_fn = warmup_stable_decay(args.lr, warmup=20,
                                stable=max(args.steps - 60, 10), decay=40)

    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size,
                                  seq_len=args.seq_len,
                                  global_batch=args.global_batch))
    injector = None
    if args.fail_at is not None:
        if dp < 3:
            print(f"NOTE: only {dp} device(s) visible - OptCC needs a DP "
                  "ring of >= 3; failure injection disabled. Run with "
                  "XLA_FLAGS=--xla_force_host_platform_device_count=8 "
                  "to see the failover path.")
        else:
            injector = FailureInjector.nic_loss(
                dp, args.fail_at, args.straggler % dp, args.ell,
                repair_step=args.repair_at)

    fault = FaultState(axis_size=dp)
    step_fn = make_dp_failover_step(model, mesh, opt, lr_fn, fault)
    state = init_train_state(model, opt)
    start = 0
    if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        state, meta = restore(args.ckpt_dir, state)
        start = int(meta["step"])
        print(f"resumed from checkpoint at step {start}")

    t0 = time.time()
    step = start
    while step < args.steps:
        if args.lose_node_at is not None and step == args.lose_node_at \
                and dp > 1:
            # Elastic rescale: half the DP members "fail". Checkpoint,
            # rebuild mesh + step on the survivors, restore, continue.
            # (Batches stay deterministic: the pipeline is keyed on
            # (seed, step), not on the shard layout.)
            ckpt = args.ckpt_dir or "/tmp/repro_elastic_ckpt"
            save(ckpt, step, state)
            dp = max(dp // 2, 1)
            devices = jax.devices()[:dp]
            mesh = Mesh(np.array(devices), ("data",))
            fault = FaultState(axis_size=dp)
            injector = None   # old ring is gone
            step_fn = make_dp_failover_step(model, mesh, opt, lr_fn,
                                            fault)
            state, _ = restore(ckpt, state)
            state = jax.device_put(state)
            print(f"step {step}: NODE LOSS - resumed on {dp} devices "
                  f"(elastic reshard from checkpoint)")
        if injector is not None:
            new_fault = injector.at_step(step, fault)
            if new_fault != fault:
                fault = new_fault
                if fault.degraded:
                    n_grad = sum(int(np.prod(x.shape)) for x in
                                 jax.tree.leaves(state.params))
                    plan = fault.plan(n_grad)
                    print(f"step {step}: DEGRADED (straggler="
                          f"{fault.straggler}, l={fault.ell}); planner "
                          f"chose {plan.algo}, predicted overhead "
                          f"{plan.predicted_overhead:.3f}x, plan built in "
                          f"{plan.gen_seconds * 1e3:.2f} ms")
                else:
                    print(f"step {step}: REPAIRED; back to native psum")
                step_fn = make_dp_failover_step(model, mesh, opt, lr_fn,
                                                fault)
        batch = jax.tree.map(jnp.asarray, data.batch(step))
        state, metrics = step_fn(state, batch)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"({(time.time() - t0):.1f}s)", flush=True)
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            save(args.ckpt_dir, step + 1, state)
        step += 1
    print("done")
    return state


if __name__ == "__main__":
    main()
