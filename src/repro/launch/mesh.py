"""Production mesh + input specs for the multi-pod dry-run.

make_production_mesh is a FUNCTION (not module-level state) so importing
this module never touches jax device state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ShapeCell, get_config
from repro.configs.base import ModelConfig


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def batch_axes(mesh: Mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype),
                                sharding=NamedSharding(mesh, spec))


def _bspec(mesh, B: int, extra_dims: int = 1) -> P:
    """Shard the leading batch dim over pod x data when divisible."""
    ba = batch_axes(mesh)
    n = np.prod([mesh.shape[a] for a in ba])
    if B % n == 0:
        return P(ba if len(ba) > 1 else ba[0], *([None] * extra_dims))
    if B % mesh.shape["data"] == 0:
        return P("data", *([None] * extra_dims))
    return P(*([None] * (extra_dims + 1)))


def token_positions_spec(cfg: ModelConfig, mesh, B, S):
    """ShapeDtypeStructs for the token inputs of one train batch."""
    bspec = _bspec(mesh, B, 1)
    batch = {
        "tokens": _sds((B, S), jnp.int32, mesh, bspec),
        "labels": _sds((B, S), jnp.int32, mesh, bspec),
    }
    if cfg.family == "whisper":
        batch["frames"] = _sds((B, cfg.n_audio_frames, cfg.d_model),
                               jnp.bfloat16, mesh, _bspec(mesh, B, 2))
    if cfg.family == "vlm":
        batch["prefix_embeds"] = _sds(
            (B, cfg.n_patch_tokens, cfg.d_model), jnp.bfloat16, mesh,
            _bspec(mesh, B, 2))
    return batch


def input_specs(arch: str, shape: ShapeCell, mesh: Mesh,
                cfg: ModelConfig | None = None) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of a cell.

    train:   {tokens, labels, extras}  (vlm text tokens shrink by the
             stubbed patch-prefix so total context == shape.seq_len)
    prefill: {tokens, extras}
    decode:  {token (B,1), pos scalar}  (cache specs built separately)
    """
    cfg = cfg or get_config(arch)
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "vlm":
        S_text = S - cfg.n_patch_tokens
    else:
        S_text = S
    if shape.kind == "train":
        batch = token_positions_spec(cfg, mesh, B, S_text)
        return batch
    if shape.kind == "prefill":
        batch = token_positions_spec(cfg, mesh, B, S_text)
        batch.pop("labels")
        return batch
    # decode: one token, cache of length S
    bspec = _bspec(mesh, B, 1)
    return {
        "tokens": _sds((B, 1), jnp.int32, mesh, bspec),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }


def cache_specs(cfg: ModelConfig, mesh: Mesh, B: int, S: int) -> dict:
    """Sharded ShapeDtypeStructs for the decode cache (family-aware).

    Sharding rules: batch over pod x data when divisible, else the
    sequence dim; KV heads over model when divisible, else the head dim
    stays unsharded and the seq dim takes model.
    """
    from repro.models import build_model
    model = build_model(cfg)
    template = jax.eval_shape(lambda: model.init_cache(B, S))
    ba = batch_axes(mesh)
    nb = int(np.prod([mesh.shape[a] for a in ba]))
    m = mesh.shape["model"]

    def spec_for(leaf):
        shp = leaf.shape
        spec = [None] * len(shp)
        if len(shp) >= 3:
            # (L, B, S-or-window, ...) layout for all families
            if shp[1] % nb == 0 and shp[1] > 1:
                spec[1] = ba if len(ba) > 1 else ba[0]
            elif shp[2] % nb == 0 and shp[2] >= nb:
                spec[2] = ba if len(ba) > 1 else ba[0]
            # model axis: KV heads (dim 3) else seq (dim 2)
            if len(shp) >= 5 and shp[3] % m == 0 and shp[3] >= m:
                spec[3] = "model"
            elif spec[2] is None and shp[2] % m == 0 and shp[2] >= m:
                spec[2] = "model"
            elif len(shp) == 3 and shp[2] % m == 0:   # rwkv tshift (L,B,d)
                spec[2] = "model"
        return jax.ShapeDtypeStruct(
            leaf.shape, leaf.dtype, sharding=NamedSharding(mesh, P(*spec)))

    return jax.tree.map(spec_for, template)
