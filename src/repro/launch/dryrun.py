import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this AOT-compiles the real program (train_step for train
shapes, prefill for prefill shapes, serve/decode step for decode shapes)
against ShapeDtypeStruct stand-ins carrying the production shardings - no
arrays are allocated. Records memory_analysis / cost_analysis / parsed
collective bytes into a JSON cache (one file per cell) that
EXPERIMENTS.md's tables and the roofline analysis read.

Usage:
  python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k \
      --mesh single [--tag baseline] [--force] [--set remat=dots] ...
  python -m repro.launch.dryrun --all [--mesh both]
"""
import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import pathlib  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import (ARCH_IDS, SHAPES, ShapeCell,  # noqa: E402
                           cell_is_applicable, get_config)
from repro.launch.mesh import (cache_specs, input_specs,  # noqa: E402
                               make_production_mesh)
from repro.models import build_model  # noqa: E402
from repro.optim import AdamWConfig, init_state  # noqa: E402
from repro.optim.schedules import constant  # noqa: E402
from repro.roofline import Roofline  # noqa: E402
from repro.roofline.hlo_parse import analyze_hlo  # noqa: E402
from repro.train import (TrainState, make_gspmd_train_step,  # noqa: E402
                         shardings_for_params)

OUT_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

# Per-arch microbatch counts for train_4k (keep per-device activations in
# the v5e HBM budget; validated via memory_analysis).
TRAIN_MICROBATCHES = {
    "gemma3-27b": 8, "arctic-480b": 8, "phi3.5-moe-42b-a6.6b": 4,
    "rwkv6-7b": 4, "qwen3-1.7b": 2, "minicpm-2b": 2, "internlm2-1.8b": 2,
    "hymba-1.5b": 2, "whisper-base": 1, "qwen2-vl-2b": 2,
}

# Baseline remat policy for train cells: without remat, the backward pass
# stores every attention-probability block across the layer scan (TBs of
# HBM traffic + temp memory). Production systems remat by default at these
# scales; --set remat=none reproduces the unrematted variant (recorded as
# hillclimb iteration 0 in EXPERIMENTS.md SPerf).
TRAIN_REMAT_DEFAULT = "full"


def _sds_like(shapes_tree, shardings_tree):
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        shapes_tree, shardings_tree)


def _replicated_sds(shapes_tree, mesh):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, P())),
        shapes_tree)


def _shard_count(sharding, shape) -> int:
    try:
        return int(np.prod([sharding.mesh.shape[a]
                            for axes in sharding.spec if axes
                            for a in ((axes,) if isinstance(axes, str)
                                      else axes)]))
    except Exception:
        return 1


def _tree_bytes_per_device(sds_tree) -> float:
    total = 0.0
    for leaf in jax.tree.leaves(sds_tree):
        nbytes = np.prod(leaf.shape) * leaf.dtype.itemsize
        total += nbytes / _shard_count(leaf.sharding, leaf.shape)
    return total


def build_cell_program(arch: str, shape: ShapeCell, mesh, cfg=None,
                       microbatches=None):
    """Returns (jitted_fn, args_sds, model_flops, extra_bytes_info)."""
    cfg = cfg or get_config(arch)
    model = build_model(cfg)
    B, S = shape.global_batch, shape.seq_len
    opt_cfg = AdamWConfig(moment_dtype=cfg.moment_dtype)

    params_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pshard = shardings_for_params(params_shapes, cfg, mesh)
    params_sds = _sds_like(params_shapes, pshard)
    n_params = sum(int(np.prod(x.shape))
                   for x in jax.tree.leaves(params_shapes))
    n_active = cfg.active_params if cfg.family == "moe" else n_params

    info = {"n_params": n_params, "n_active": n_active,
            "params_bytes_per_device": _tree_bytes_per_device(params_sds)}

    if shape.kind == "train":
        nm = microbatches or TRAIN_MICROBATCHES.get(arch, 1)
        step = make_gspmd_train_step(model, mesh, opt_cfg, constant(1e-4),
                                     num_microbatches=nm)
        opt_shapes = jax.eval_shape(lambda p: init_state(p, opt_cfg),
                                    params_sds)
        opt_sds = {
            "mu": _sds_like(opt_shapes["mu"], pshard),
            "nu": _sds_like(opt_shapes["nu"], pshard),
            "count": jax.ShapeDtypeStruct((), jnp.int32),
        }
        state_sds = TrainState(params_sds, opt_sds,
                               jax.ShapeDtypeStruct((), jnp.int32))
        batch_sds = input_specs(arch, shape, mesh, cfg)
        info["opt_bytes_per_device"] = _tree_bytes_per_device(opt_sds)
        info["microbatches"] = nm
        # 6 N D for train (fwd+bwd), D = total tokens
        model_flops = 6.0 * n_active * B * S
        fn = jax.jit(step, donate_argnums=(0,))
        return fn, (state_sds, batch_sds), model_flops, info

    if shape.kind == "prefill":
        batch_sds = input_specs(arch, shape, mesh, cfg)
        fn = jax.jit(lambda p, b: model.prefill(p, b))
        model_flops = 2.0 * n_active * B * S
        return fn, (params_sds, batch_sds), model_flops, info

    # decode
    csds = cache_specs(cfg, mesh, B, S)
    info["cache_bytes_per_device"] = _tree_bytes_per_device(csds)
    tok = input_specs(arch, shape, mesh, cfg)
    fn = jax.jit(lambda p, c, t, pos: model.decode_step(p, c, t, pos),
                 donate_argnums=(1,))
    model_flops = 2.0 * n_active * B * 1
    return fn, (params_sds, csds, tok["tokens"], tok["pos"]), \
        model_flops, info


def run_cell(arch: str, shape: ShapeCell, mesh_kind: str, tag="baseline",
             force=False, overrides=None, microbatches=None) -> dict:
    out_path = OUT_DIR / f"{arch}__{shape.name}__{mesh_kind}__{tag}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())
    OUT_DIR.mkdir(parents=True, exist_ok=True)

    ok, why = cell_is_applicable(arch, shape)
    rec = {"arch": arch, "shape": shape.name, "mesh": mesh_kind,
           "tag": tag, "timestamp": time.time()}
    if not ok:
        rec.update({"status": "skipped", "reason": why})
        out_path.write_text(json.dumps(rec, indent=1))
        return rec

    cfg = get_config(arch)
    if shape.kind == "train" and cfg.family in ("dense", "moe", "vlm"):
        cfg = cfg.replace(remat=TRAIN_REMAT_DEFAULT)
    if overrides:
        cfg = cfg.replace(**overrides)
        rec["overrides"] = {k: str(v) for k, v in overrides.items()}

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = int(np.prod(list(mesh.shape.values())))
    from repro.models.shardctx import set_batch_axes
    set_batch_axes(tuple(a for a in ("pod", "data")
                         if a in mesh.axis_names))
    try:
        t0 = time.time()
        fn, args, model_flops, info = build_cell_program(
            arch, shape, mesh, cfg, microbatches)
        with mesh:
            lowered = fn.lower(*args)
            t_lower = time.time() - t0
            t0 = time.time()
            compiled = lowered.compile()
            t_compile = time.time() - t0

        cost = {}
        try:
            ca = compiled.cost_analysis()
            if isinstance(ca, (list, tuple)):
                ca = ca[0]
            cost = {k: float(v) for k, v in ca.items()
                    if isinstance(v, (int, float)) and not k.startswith("u")}
        except Exception as e:  # pragma: no cover
            rec["cost_analysis_error"] = str(e)

        mem = {}
        try:
            ma = compiled.memory_analysis()
            for field in ("argument_size_in_bytes", "output_size_in_bytes",
                          "temp_size_in_bytes", "alias_size_in_bytes",
                          "generated_code_size_in_bytes"):
                if hasattr(ma, field):
                    mem[field] = int(getattr(ma, field))
        except Exception as e:  # pragma: no cover
            rec["memory_analysis_error"] = str(e)

        t0 = time.time()
        text = compiled.as_text()
        hlo = analyze_hlo(text)   # loop-trip-aware FLOPs/bytes/collectives
        t_parse = time.time() - t0

        roof = Roofline(
            flops=hlo.flops,
            bytes_hbm=hlo.hbm_bytes,
            bytes_collective=hlo.collective_bytes,
            model_flops=model_flops,
            chips=chips)

        rec.update({
            "status": "ok",
            "chips": chips,
            "cost_analysis_per_iter": cost,   # XLA's (loop bodies once)
            "memory_analysis": mem,
            "collectives": {
                "bytes_by_kind": hlo.collective_by_kind,
                "total_bytes": hlo.collective_bytes,
                "n_ops": hlo.n_collectives,
                "warnings": hlo.warnings[:10],
            },
            "trip_counts": {k: v for k, v in
                            sorted(hlo.trip_counts.items())[:40]},
            "roofline": roof.to_dict(),
            "info": info,
            "hlo_lines": len(text.splitlines()),
            "timings": {"lower_s": t_lower, "compile_s": t_compile,
                        "parse_s": t_parse},
        })
    except Exception as e:
        rec.update({"status": "error", "error": repr(e),
                    "traceback": traceback.format_exc()[-4000:]})
    finally:
        set_batch_axes(None)
    out_path.write_text(json.dumps(rec, indent=1))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--set", action="append", default=[],
                    help="cfg override key=value (hillclimbing)")
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, _, v = kv.partition("=")
        for cast in (int, float):
            try:
                v = cast(v)
                break
            except ValueError:
                continue
        if v in ("True", "False"):
            v = v == "True"
        overrides[k] = v

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = SHAPES if (args.all or not args.shape) else \
        [s for s in SHAPES if s.name == args.shape]

    for mesh_kind in meshes:
        for arch in archs:
            for shape in shapes:
                t0 = time.time()
                rec = run_cell(arch, shape, mesh_kind, tag=args.tag,
                               force=args.force,
                               overrides=overrides or None,
                               microbatches=args.microbatches)
                status = rec.get("status")
                extra = ""
                if status == "ok":
                    r = rec["roofline"]
                    extra = (f"dom={r['dominant']} "
                             f"tc={r['t_compute_s']:.3e} "
                             f"tm={r['t_memory_s']:.3e} "
                             f"tcoll={r['t_collective_s']:.3e}")
                elif status == "error":
                    extra = rec.get("error", "")[:120]
                print(f"[{mesh_kind}] {arch} x {shape.name}: {status} "
                      f"({time.time() - t0:.1f}s) {extra}", flush=True)


if __name__ == "__main__":
    main()
