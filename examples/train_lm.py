"""End-to-end LM training with a mid-run network failure.

Trains a reduced qwen3-family model on the synthetic pipeline for a few
hundred steps; at --fail-at a NIC degradation is injected (the failure
detector fires), the OptCC planner rebuilds the gradient-sync collective
online, and training continues without a restart; at --repair-at the link
heals and the native psum path returns. Checkpoints are written
periodically and the run auto-resumes from the latest one.

    PYTHONPATH=src python examples/train_lm.py               # ~5 min CPU
    PYTHONPATH=src python examples/train_lm.py --steps 400 \
        --fail-at 150 --repair-at 300

Run it on 8 virtual devices to see a real multi-member DP ring:
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/train_lm.py
"""
import sys

sys.path.insert(0, "src")

from repro.launch.train import main as train_main


if __name__ == "__main__":
    argv = sys.argv[1:]
    defaults = ["--arch", "qwen3-1.7b", "--smoke", "--steps", "200",
                "--fail-at", "60", "--repair-at", "140",
                "--ckpt-dir", "/tmp/repro_ckpt", "--ckpt-every", "50"]
    # user-supplied flags win over defaults
    seen = {a for a in argv if a.startswith("--")}
    final = list(argv)
    i = 0
    while i < len(defaults):
        if defaults[i] not in seen:
            final.extend(defaults[i:i + 2])
        i += 2
    train_main(final)
