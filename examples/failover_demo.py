"""Failover equivalence on a real 8-member DP ring (virtual devices).

Spawns a subprocess with 8 forced host devices and trains the same model
twice: once healthy (native psum gradient sync) and once with member 3
degraded to 4/7 bandwidth (OptCC sync). The parameter trajectories must
match to fp tolerance - the paper's algorithm changes WHERE bytes flow,
never WHAT is computed.

    PYTHONPATH=src python examples/failover_demo.py

With ``--trace PATH`` the demo instead simulates the same degraded
scenario's OptCC schedule with telemetry, writes a Chrome trace (open in
chrome://tracing or Perfetto) and prints the critical-path stage breakdown
- no JAX subprocess is run. Add ``--algo NAME`` to force any algorithm
registered in `repro.core.registry` (ring, optcc, dbtree, torus2d, ...)
instead of letting the planner choose.

With ``--timeline [TRACE.json]`` the demo replays the degraded scenario
under a time-varying failure timeline (default: member 3 recovers at
0.35 T0; or any `ci/traces/*.json` file) and prints the static (no-replan)
vs mid-flight-replanned makespans next to the timeline lower bound - the
quantified payoff of re-planning when the fault pattern changes mid-
collective. Also JAX-free.
"""
import argparse
import os
import pathlib
import subprocess
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent
                       / "src"))

REPO = pathlib.Path(__file__).resolve().parent.parent

CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.configs import get_config
from repro.models import build_model
from repro.optim import AdamWConfig
from repro.optim.schedules import constant
from repro.train import init_train_state, make_dp_failover_step
from repro.comms.fault import FaultState
from repro.data import DataConfig, SyntheticLM

cfg = get_config("qwen3-1.7b", smoke=True)
model = build_model(cfg)
opt = AdamWConfig(weight_decay=0.0)
mesh = Mesh(np.array(jax.devices()), ("data",))
data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                              global_batch=8))
fault = FaultState(axis_size=8, straggler=3, ell=1.75)
plan = fault.plan(n_elements=1_000_000)
print(f"degraded member 3 (l=1.75): planner chose {plan.algo}, "
      f"predicted overhead {plan.predicted_overhead:.3f}x vs healthy")

steps = {
    "healthy": make_dp_failover_step(model, mesh, opt, constant(1e-3),
                                     FaultState(axis_size=8)),
    "degraded": make_dp_failover_step(model, mesh, opt, constant(1e-3),
                                      fault),
}
states = {k: init_train_state(model, opt, seed=11) for k in steps}
for i in range(5):
    b = jax.tree.map(jnp.asarray, data.batch(i))
    line = f"step {i}:"
    for k in steps:
        states[k], m = steps[k](states[k], b)
        line += f"  {k} loss={float(m['loss']):.5f}"
    print(line)
diff = max(jax.tree.leaves(jax.tree.map(
    lambda a, b: float(jnp.max(jnp.abs(a - b))),
    states["healthy"].params, states["degraded"].params)))
print(f"max param divergence after 5 steps: {diff:.2e}")
assert diff < 1e-5
print("OK: OptCC-synced training is numerically identical to psum")
"""


def trace_scenario(path: str, algo: str = "auto") -> None:
    """Simulate the demo's degraded scenario (p=8, member 3 at l=1.75) with
    telemetry and write a Chrome trace plus a stage breakdown to stdout."""
    from repro import obs
    from repro.core.model import BandwidthProfile
    from repro.core.planner import make_plan
    from repro.core.simulator import simulate

    profile = BandwidthProfile.single_straggler(8, 1.75, straggler=3)
    plan = make_plan(profile, n=1_000_000, k=16, materialize="arrays",
                     algo=algo)
    res = simulate(plan.schedule, telemetry=True)
    obs.write_chrome_trace(res.telemetry, path, name="failover_demo")
    print(f"wrote {path}: algo={plan.algo} topology={plan.topology} "
          f"T={res.makespan:.6g} "
          f"(T0={plan.t0:.6g}, overhead {res.makespan / plan.t0:.3f}x, "
          f"{res.telemetry.nflows} flows)")
    for stage, v in sorted(obs.stage_breakdown(res.telemetry).items(),
                           key=lambda kv: -kv[1]):
        print(f"  {stage:10s} {v:14.3f}  ({v / res.makespan:6.1%})")


def timeline_scenario(trace_path: str | None) -> None:
    """Replay the demo's degraded scenario under a failure timeline and
    print no-replan vs replanned makespans next to the lower bound."""
    from repro.core import lower_bounds as lb
    from repro.core.model import BandwidthProfile, FaultTimeline
    from repro.core.planner import replay

    p, n = 8, 1_000_000
    profile = BandwidthProfile.single_straggler(p, 1.75, straggler=3)
    scale = lb.t0_fault_free(p, n, 1)
    if trace_path is None:
        name = "built-in recovery (member 3 heals at 0.35 T0)"
        events = [(0.0, 3, 1.75), (0.35 * scale, 3, 1.0)]
    else:
        from repro.sweeps.scenarios import load_trace
        tr = load_trace(trace_path)
        name = tr["name"]
        # Trace event times are in units of T0 (scale-free); ranks wrap.
        events = [(t * scale, int(r) % p, ell) for t, r, ell in tr["events"]]
    tl = FaultTimeline.make(events)
    rr = replay(profile, n, tl, k=16)
    print(f"timeline: {name} ({len(events)} events, p={p}, n={n})")
    print(f"  fault-free optimum T0     {rr.t0:14.1f}")
    print(f"  timeline lower bound      {rr.lower_bound:14.1f}  "
          f"({rr.lower_bound / rr.t0:.3f}x T0)")
    print(f"  static plan, no replan    {rr.t_noreplan:14.1f}  "
          f"({rr.t_noreplan / rr.t0:.3f}x T0)")
    print(f"  mid-flight replanned      {rr.t_replan:14.1f}  "
          f"({rr.t_replan / rr.t0:.3f}x T0, {rr.replans} replans)")
    if rr.adopted_replan:
        print(f"  re-planning saved {rr.t_noreplan - rr.t_replan:.1f} "
              f"({1 - rr.t_replan / rr.t_noreplan:.1%} of the no-replan "
              f"makespan)")
    else:
        print("  re-planning could not beat riding the original schedule")

    # The same timeline through an *imperfect* detector: probes lag,
    # quantize and occasionally lie, and a debounced controller decides
    # when an estimate is worth a re-plan.
    from repro.detect import ControllerConfig, DetectorConfig
    det = DetectorConfig.default(scale=scale)
    rr_det = replay(profile, n, tl, k=16, detector=det,
                    controller=ControllerConfig(policy="debounce"))
    d = rr_det.detection
    print(f"\nimperfect detector (probe every {det.probe_interval:.0f}, "
          f"latency {det.latency:.0f}, noise {det.noise:g}, "
          f"quant {det.quant:g}, fp {det.fp_rate:g}, fn {det.fn_rate:g}; "
          f"debounced x3):")
    true_rows = [f"t={t:9.1f} r{rank} l={ell:g}"
                 for t, rank, ell in sorted(
                     (float(t), r, v) for r, ch in
                     tl.changes(profile).items() for t, v in ch)]
    est_rows = [f"t={ev.t:9.1f} r{ev.rank} l={ev.ell:g}"
                for ev in d.timeline.events]
    width = max([24] + [len(s) for s in true_rows])
    print(f"  {'true profile changes':{width}s} | detector estimate")
    for i in range(max(len(true_rows), len(est_rows))):
        left = true_rows[i] if i < len(true_rows) else ""
        right = est_rows[i] if i < len(est_rows) else ""
        print(f"  {left:{width}s} | {right}")
    lag = (f"{rr_det.detect_lag_mean:.1f}"
           if rr_det.detect_lag_mean is not None else "-")
    print(f"  detected makespan         {rr_det.t_replan:14.1f}  "
          f"({rr_det.t_replan / rr.t_replan:.3f}x the zero-delay oracle; "
          f"{rr_det.replans} replans, {rr_det.false_replans} false, "
          f"{rr_det.suppressed} suppressed, mean lag {lag})")

    # Smoke check: on a trace that is *nothing but* false positives the
    # debounced controller must hold its fire - a re-plan here means the
    # debounce policy regressed, so the demo fails loudly.
    fp_det = DetectorConfig(probe_interval=0.04 * scale,
                            latency=0.01 * scale, fp_rate=0.25, seed=7)
    rr_fp = replay(profile, n, FaultTimeline.make([]), k=16,
                   detector=fp_det,
                   controller=ControllerConfig(policy="debounce"))
    print(f"  pure-FP trace (fp=0.25): debounced controller made "
          f"{rr_fp.replans} replans, suppressed {rr_fp.suppressed} blips")
    if rr_fp.replans:
        print("FAIL: debounce re-planned on a pure false-positive trace",
              file=sys.stderr)
        sys.exit(1)


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="write a Chrome trace of the degraded scenario's "
                         "simulated schedule and exit (skips the JAX run)")
    ap.add_argument("--algo", default="auto",
                    help="schedule algorithm for --trace: 'auto' (planner "
                         "picks) or any name in repro.core.registry, e.g. "
                         "ring, optcc, dbtree, torus2d (default: auto)")
    ap.add_argument("--timeline", metavar="TRACE.json", nargs="?",
                    const="", default=None,
                    help="replay the degraded scenario under a failure "
                         "timeline (default: a mid-flight recovery; or a "
                         "ci/traces/*.json file) and print static vs "
                         "replanned makespans (skips the JAX run)")
    args = ap.parse_args()
    if args.timeline is not None:
        timeline_scenario(args.timeline or None)
        return
    if args.trace:
        trace_scenario(args.trace, algo=args.algo)
        return
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", CHILD], env=env,
                          text=True)
    sys.exit(proc.returncode)


if __name__ == "__main__":
    main()
