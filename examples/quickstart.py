"""Quickstart: what happens to your AllReduce when a NIC dies?

Builds a bandwidth profile for a 16-GPU DP group where one server lost
half its NICs, asks the planner for a schedule, simulates it against the
baselines, and prints the paper's headline comparison.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

from repro.core import BandwidthProfile, make_plan, simulate
from repro.core import lower_bounds as lb
from repro.core.baselines import r2ccl_time


def main():
    p, ell, k = 16, 2.0, 96           # one GPU lost 4/8 NICs -> l = 2
    n = k * (p - 1) * 64              # gradient buffer (elements)
    t0 = lb.t0_fault_free(p, n)

    print(f"DP group: {p} GPUs, straggler at rank 0 with l={ell} "
          f"(50% bandwidth), buffer n={n} elements\n")

    plan = make_plan(BandwidthProfile.single_straggler(p, ell), n, k)
    print(f"planner: algo={plan.algo}, built in "
          f"{plan.gen_seconds * 1e3:.1f} ms, predicted overhead "
          f"{plan.predicted_overhead:.3f}x, lower bound "
          f"{plan.lower_bound / t0:.3f}x")

    t_optcc = simulate(plan.schedule).makespan
    ring_plan = make_plan(plan.profile, n, algo="ring")
    t_iccl = simulate(ring_plan.schedule).makespan
    t_r2 = r2ccl_time(p, n, ell)

    print("\ncompletion time vs fault-free NCCL ring (lower is better):")
    for name, t in (("NCCL_NoFailure", t0), ("OptCC (ours)", t_optcc),
                    ("R2CCL (SOTA)", t_r2), ("ICCL (plain ring)", t_iccl)):
        bar = "#" * int(40 * t / t_iccl)
        print(f"  {name:18s} {t / t0:5.2f}x  {bar}")

    print(f"\nOptCC overhead: {(t_optcc / t0 - 1) * 100:.1f}% "
          f"(paper: 2-6%); information-theoretic minimum: "
          f"{(plan.lower_bound / t0 - 1) * 100:.1f}%")


if __name__ == "__main__":
    main()
