"""Serve a small model: prefill a batched prompt, greedy-decode new tokens.

Exercises the same prefill/decode_step programs the decode_* dry-run cells
lower, on a reduced config at runnable scale. Works for any of the 10
architectures (--arch), including the SSM (rwkv6-7b) whose "KV cache" is
an O(1) recurrent state.

    PYTHONPATH=src python examples/serve_decode.py --arch qwen3-1.7b
    PYTHONPATH=src python examples/serve_decode.py --arch rwkv6-7b
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.train.serve import generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=24)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    model = build_model(cfg)
    params = jax.jit(model.init)(jax.random.PRNGKey(0))
    n_params = model.param_count(params)
    print(f"{cfg.name}: {n_params / 1e6:.2f}M params "
          f"({cfg.family}), vocab={cfg.vocab_size}")

    rng = np.random.default_rng(0)
    prompt = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)))
    extras = None
    if cfg.family == "whisper":
        extras = {"frames": jnp.asarray(rng.standard_normal(
            (args.batch, cfg.n_audio_frames, cfg.d_model)), jnp.float32)}

    t0 = time.time()
    out = generate(model, params, prompt, args.new_tokens,
                   batch_extras=extras)
    dt = time.time() - t0
    print(f"prefill {args.prompt_len} + decode {args.new_tokens} tokens "
          f"x{args.batch} in {dt:.1f}s "
          f"({args.batch * args.new_tokens / dt:.1f} tok/s on CPU)")
    print("sampled continuations (token ids):")
    for b in range(min(args.batch, 2)):
        print(f"  [{b}] {out[b].tolist()}")
    # greedy decode is deterministic
    out2 = generate(model, params, prompt, args.new_tokens,
                    batch_extras=extras)
    assert (out == out2).all(), "greedy decode must be deterministic"
    print("determinism check OK")


if __name__ == "__main__":
    main()
