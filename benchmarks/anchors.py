"""Headline-claim anchors at production pipeline depth (k=256, p=64).

The paper's claim: OptCC within 2-6% of NCCL_NoFailure when the worst NIC
retains >= 50% bandwidth. Small-k points in fig8 carry the pipeline-fill
cost ((k+3)/k with our 4-stage-deep pipeline); these anchors use k=256 as
a production gradient buffer would (hundreds of MB -> hundreds of
segments). Scenarios run through the sweep engine.
"""
from __future__ import annotations

from repro.core import BandwidthProfile
from benchmarks.common import row, score, wall


def run():
    rows = []
    p, k = 64, 256
    n = k * (p - 1) * 32
    for ell in (8 / 7, 1.5, 2.0):
        prof = BandwidthProfile.single_straggler(p, ell)
        r = score(prof, n, k)
        rows.append(row(f"anchor_p{p}_k{k}_l{ell:.2f}_optcc", wall(r),
                        r.overhead_optcc, "paper claim: 1.02-1.06"))
    prof = BandwidthProfile.multi_straggler(p, [4 / 3, 8 / 7])
    r = score(prof, n, k)
    rows.append(row(f"anchor_p{p}_k{k}_m2_optcc", wall(r), r.overhead_optcc,
                    "paper claim: <=1.085"))
    return rows
