"""Headline-claim anchors at production pipeline depth (k=256, p=64).

The paper's claim: OptCC within 2-6% of NCCL_NoFailure when the worst NIC
retains >= 50% bandwidth. Small-k points in fig8 carry the pipeline-fill
cost ((k+3)/k with our 4-stage-deep pipeline); these anchors use k=256 as
a production gradient buffer would (hundreds of MB -> hundreds of
segments).
"""
from __future__ import annotations

from repro.core import BandwidthProfile
from repro.core import lower_bounds as lb
from benchmarks.common import row, sim_optcc


def run():
    rows = []
    p, k = 64, 256
    n = k * (p - 1) * 32
    t0 = lb.t0_fault_free(p, n)
    for ell in (8 / 7, 1.5, 2.0):
        prof = BandwidthProfile.single_straggler(p, ell)
        t, wall = sim_optcc(prof, n, k)
        rows.append(row(f"anchor_p{p}_k{k}_l{ell:.2f}_optcc", wall, t / t0,
                        "paper claim: 1.02-1.06"))
    ells = [4 / 3, 8 / 7]
    prof = BandwidthProfile.multi_straggler(p, ells)
    t, wall = sim_optcc(prof, n, k)
    rows.append(row(f"anchor_p{p}_k{k}_m2_optcc", wall, t / t0,
                    "paper claim: <=1.085"))
    return rows
