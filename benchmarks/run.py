"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived[,note]`` CSV. Derived is the paper's
metric (completion time / fault-free T0 unless noted). The SimAI stand-in
is core.simulator (deterministic bandwidth-bound flow model).
"""
from __future__ import annotations

import sys
import time

from benchmarks import (anchors, appf_large_message, fig8_single_straggler,
                        fig9_multi_straggler, fig10_multi_gpu,
                        kernels_micro, schedule_gen_speed, sweep_summary,
                        table1_bounds)
from benchmarks.common import emit

MODULES = [
    ("fig8", fig8_single_straggler),
    ("fig9", fig9_multi_straggler),
    ("fig10", fig10_multi_gpu),
    ("table1", table1_bounds),
    ("schedgen", schedule_gen_speed),
    ("appF", appf_large_message),
    ("kernels", kernels_micro),
    ("anchors", anchors),
    ("sweep", sweep_summary),
]


def main() -> None:
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived,note")
    for name, mod in MODULES:
        if only and only not in name:
            continue
        t0 = time.time()
        rows = mod.run()
        emit(rows)
        print(f"# {name}: {len(rows)} rows in {time.time() - t0:.1f}s",
              file=sys.stderr)


if __name__ == '__main__':
    main()
