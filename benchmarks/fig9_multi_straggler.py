"""Figure 9: two stragglers, one GPU/server.

(a,b) l1=4/3, l2=8/7; (c,d) l1=2, l2=4/3; (e) l1=l2 sweep.
Derived = completion time / T0. Scenarios run through the sweep engine.
"""
from __future__ import annotations

from repro.core import BandwidthProfile
from benchmarks.common import row, score, wall


def run():
    rows = []
    for tag, ells in (("fig9a", [4 / 3, 8 / 7]), ("fig9c", [2.0, 4 / 3])):
        for p, k in ((16, 48), (32, 32), (64, 16)):
            n = k * (p - 2) * 64
            prof = BandwidthProfile.multi_straggler(p, ells)
            r = score(prof, n, k, simulate_ring=True)
            rows.append(row(f"{tag}_p{p}_optcc", wall(r), r.overhead_optcc))
            rows.append(row(f"{tag}_p{p}_iccl", r.ring_sim_seconds,
                            r.overhead_ring))
            rows.append(row(f"{tag}_p{p}_lb", 0.0, r.overhead_lb))
    # (e): equal-l sweep at p=32.
    p, k = 32, 32
    n = k * (p - 2) * 64
    for ell in (8 / 7, 4 / 3, 2.0, 8 / 3):
        prof = BandwidthProfile.multi_straggler(p, [ell, ell])
        r = score(prof, n, k)
        rows.append(row(f"fig9e_l{ell:.2f}_optcc", wall(r), r.overhead_optcc))
        rows.append(row(f"fig9e_l{ell:.2f}_lb", 0.0, r.overhead_lb))
    return rows
