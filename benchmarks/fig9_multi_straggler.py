"""Figure 9: two stragglers, one GPU/server.

(a,b) l1=4/3, l2=8/7; (c,d) l1=2, l2=4/3; (e) l1=l2 sweep.
Derived = completion time / T0.
"""
from __future__ import annotations

from repro.core import BandwidthProfile
from repro.core import lower_bounds as lb
from benchmarks.common import row, sim_optcc, sim_ring


def run():
    rows = []
    for tag, ells in (("fig9a", [4 / 3, 8 / 7]), ("fig9c", [2.0, 4 / 3])):
        for p, k in ((16, 48), (32, 32), (64, 16)):
            n = k * (p - 2) * 64
            t0 = lb.t0_fault_free(p, n)
            prof = BandwidthProfile.multi_straggler(p, ells)
            t, wall = sim_optcc(prof, n, k)
            rows.append(row(f"{tag}_p{p}_optcc", wall, t / t0))
            t_r, wall_r = sim_ring(prof, n)
            rows.append(row(f"{tag}_p{p}_iccl", wall_r, t_r / t0))
            rows.append(row(f"{tag}_p{p}_lb", 0.0,
                            lb.lb_multi_straggler(p, n, ells) / t0))
    # (e): equal-l sweep at p=32.
    p, k = 32, 32
    n = k * (p - 2) * 64
    t0 = lb.t0_fault_free(p, n)
    for ell in (8 / 7, 4 / 3, 2.0, 8 / 3):
        prof = BandwidthProfile.multi_straggler(p, [ell, ell])
        t, wall = sim_optcc(prof, n, k)
        rows.append(row(f"fig9e_l{ell:.2f}_optcc", wall, t / t0))
        rows.append(row(f"fig9e_l{ell:.2f}_lb", 0.0,
                        lb.lb_multi_straggler(p, n, [ell, ell]) / t0))
    return rows
