"""Sweep-grid summary rows: the statistical view the four figures can't give.

Runs a thinned smoke grid through the sweep engine and emits the artifact's
summary percentiles as CSV rows (derived = the percentile value). The full
grid with per-scenario records is `python -m repro.sweeps --smoke|--full`.
"""
from __future__ import annotations

import os

from repro.sweeps import build_artifact, run_sweep, smoke_grid
from benchmarks.common import pct_rows, row


def run():
    specs = smoke_grid(seed=0)[::4]          # every 4th scenario: ~1/4 cost
    results = run_sweep(specs, workers=min(os.cpu_count() or 1, 8),
                        telemetry=True)
    art = build_artifact(results, profile="smoke/4", seed=0,
                         deterministic=False, telemetry=True)
    rows = []
    for group, stats in [("all", art["summary"]["overall"])] + \
            sorted(art["summary"]["by_family"].items()):
        for key in ("overhead_optcc_p50", "overhead_optcc_p99",
                    "optcc_vs_lb_p99"):
            rows.append(row(f"sweep_{group}_{key}", 0.0, stats[key],
                            f"count={stats['count']}"))
    # Degraded-ring (ICCL baseline) overhead distribution - the artifact
    # summary doesn't carry it, so derive it from the raw results here.
    ring_ov = [r.overhead_ring for r in results if r.overhead_ring is not None]
    if ring_ov:
        rows.extend(pct_rows("sweep_all_overhead_ring", ring_ov,
                             f"count={len(ring_ov)}"))
    # Per-stage critical-path p99 overheads (telemetry summaries).
    for stage, st in sorted(art["summary"]["overall"]["stages"].items()):
        rows.append(row(f"sweep_stage_{stage.replace(':', '_')}_p99", 0.0,
                        st["overhead_p99"], f"count={st['count']}"))
    return rows
