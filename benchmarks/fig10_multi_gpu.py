"""Figure 10 / Appendix F: multi-GPU/server DP groups (g GPUs share the
degraded server's NIC pool). Derived = completion time / T0(g).

Reported for both NVLink provisionings: the paper's theoretical minimum
(g-1)x NIC and the DGX-realistic 12x (footnote 4). Scenarios run through
the sweep engine.
"""
from __future__ import annotations

import dataclasses

from repro.core import BandwidthProfile
from repro.core import lower_bounds as lb
from benchmarks.common import row, score, wall


def run():
    rows = []
    g = 4
    for tag, ell in (("fig10a", 8 / 7), ("fig10c", 2.0)):
        for q, k in ((8, 24), (16, 12)):
            p = g * q
            n = g * k * (q - 1) * 64
            t0 = lb.t0_fault_free(p, n, g)
            for nv, nvtag in ((None, "nvmin"), (12.0, "nv12")):
                prof = dataclasses.replace(
                    BandwidthProfile.single_straggler(p, ell, g=g),
                    nvlink_mult=nv)
                r = score(prof, n, k)
                rows.append(row(f"{tag}_q{q}_optcc_{nvtag}", wall(r),
                                r.overhead_optcc))
            rows.append(row(f"{tag}_q{q}_lb", 0.0,
                            lb.lb_multi_gpu_tight(p, n, ell, g) / t0))
    # (e): l sweep at q=8.
    q, k = 8, 24
    p = g * q
    n = g * k * (q - 1) * 64
    for ell in (8 / 7, 2.0, 8 / 3, 4.0):
        prof = dataclasses.replace(
            BandwidthProfile.single_straggler(p, ell, g=g),
            nvlink_mult=12.0)
        r = score(prof, n, k)
        rows.append(row(f"fig10e_l{ell:.2f}_optcc", wall(r),
                        r.overhead_optcc))
        rows.append(row(f"fig10e_l{ell:.2f}_lb", 0.0, r.overhead_lb))
    return rows
