"""Kernel microbenchmarks (interpret-mode shapes: correctness-scale only;
wall times on CPU are NOT TPU perf - the derived column reports the
kernel's modeled HBM traffic advantage vs the unfused jnp path instead).
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import row


def run():
    rows = []
    rng = np.random.default_rng(0)

    # chunk_reduce: modeled traffic ratio = (W reads + 1 write) vs
    # jnp pairwise adds ((2 reads + 1 write) * (W-1)).
    from repro.kernels.chunk_reduce.ops import chunk_reduce
    W, N = 8, 1 << 16
    x = jnp.asarray(rng.standard_normal((W, N)), jnp.float32)
    t0 = time.perf_counter()
    chunk_reduce(x, interpret=True).block_until_ready()
    dt = time.perf_counter() - t0
    traffic_kernel = (W + 1) * N * 4
    traffic_jnp = 3 * (W - 1) * N * 4
    rows.append(row("kernel_chunk_reduce_w8", dt,
                    traffic_jnp / traffic_kernel, "modeled HBM advantage"))

    # flash attention: traffic advantage vs materialized scores at S=4096.
    from repro.kernels.flash_attention.ops import flash_attention
    B, S, H, KV, hd = 1, 128, 4, 2, 32
    q = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((B, S, KV, hd)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((B, S, KV, hd)), jnp.bfloat16)
    t0 = time.perf_counter()
    flash_attention(q, k, v, bq=64, bkv=64, interpret=True
                    ).block_until_ready()
    dt = time.perf_counter() - t0
    S_big = 4096
    qkv_bytes = 4 * S_big * hd * 2                 # q,k,v,o per head
    scores_bytes = 2 * S_big * S_big * 4           # s write+read, fp32
    rows.append(row("kernel_flash_attention", dt,
                    (qkv_bytes + scores_bytes) / qkv_bytes,
                    "modeled HBM advantage at S=4096"))

    # wkv: state stays in VMEM -> advantage = state round-trips avoided.
    from repro.kernels.wkv.ops import wkv
    B, S, H, hd = 1, 64, 2, 16
    r, kk, vv = [jnp.asarray(rng.standard_normal((B, S, H, hd)),
                             jnp.float32) for _ in range(3)]
    w = jnp.asarray(rng.uniform(0.5, 0.99, (B, S, H, hd)), jnp.float32)
    u = jnp.asarray(rng.standard_normal((H, hd)), jnp.float32)
    t0 = time.perf_counter()
    wkv(r, kk, vv, w, u, interpret=True)[0].block_until_ready()
    dt = time.perf_counter() - t0
    hd_big = 64
    io_bytes = 5 * hd_big * 4                      # r,k,v,w,o per token
    state_bytes = 2 * hd_big * hd_big * 4          # state r+w per token
    rows.append(row("kernel_wkv", dt,
                    (io_bytes + state_bytes) / io_bytes,
                    "modeled HBM advantage (state in VMEM)"))
    return rows
