"""Table 1 / Appendix A: lower-bound table + paper headline numbers.

Derived values:
  * LB overhead (LB/T0) across the three settings and both regimes;
  * the abstract's claims: <1% unavoidable overhead at p=128 for l<=2;
    R2CCL's 57% overhead at 50% bandwidth loss (p=8).
"""
from __future__ import annotations

from repro.core import lower_bounds as lb
from repro.core.baselines import r2ccl_time
from benchmarks.common import row


def run():
    rows = []
    n = 1.0
    for p in (16, 128):
        t0 = lb.t0_fault_free(p, n)
        for ell in (1.5, 2.0, 3.0):
            rows.append(row(f"table1_single_p{p}_l{ell}", 0.0,
                            lb.lb_single_straggler_tight(p, n, ell) / t0))
        rows.append(row(f"table1_multi_p{p}_l21.5", 0.0,
                        lb.lb_multi_straggler(p, n, [2.0, 1.5]) / t0))
        g = 4
        t0g = lb.t0_fault_free(p * g, n, g)
        rows.append(row(f"table1_gpu4_p{p * g}_l2", 0.0,
                        lb.lb_multi_gpu_tight(p * g, n, 2.0, g) / t0g))
    # headline claims
    over128 = lb.lb_single_straggler_tight(128, n, 2.0) / \
        lb.t0_fault_free(128, n) - 1.0
    rows.append(row("claim_lb_overhead_p128_l2", 0.0, over128,
                    "paper: <1%"))
    r2_over = r2ccl_time(8, n, 2.0) / lb.t0_fault_free(8, n) - 1.0
    rows.append(row("claim_r2ccl_overhead_p8_l2", 0.0, r2_over,
                    "paper: up to 57%"))
    return rows
