"""Figure 8: single straggler, one GPU/server DP groups.

(a,b) straggler loses 1/8 NICs (l=8/7~1.14); (c,d) loses 4/8 (l=2);
(e) varying l. Derived metric = completion time / T0 (NCCL_NoFailure=1.0).
Compared: OptCC (simulated), ICCL (simulated degraded ring), R2CCL
(paper's closed form), LB (Theorem 6). Simulation + scoring run through the
sweep engine (repro.sweeps); this module only declares the scenarios.
"""
from __future__ import annotations

from repro.core import BandwidthProfile
from repro.core import lower_bounds as lb
from repro.core.baselines import r2ccl_time
from benchmarks.common import row, score, wall


def run():
    rows = []
    # (a)/(c): sweep p at fixed l. k chosen so segments stay ~constant work.
    for tag, ell in (("fig8a", 8 / 7), ("fig8c", 2.0)):
        for p, k in ((8, 48), (16, 48), (32, 32), (64, 16)):
            n = k * (p - 1) * 64
            prof = BandwidthProfile.single_straggler(p, ell)
            r = score(prof, n, k, simulate_ring=True)
            rows.append(row(f"{tag}_p{p}_optcc", wall(r), r.overhead_optcc))
            rows.append(row(f"{tag}_p{p}_iccl", r.ring_sim_seconds,
                            r.overhead_ring))
            rows.append(row(f"{tag}_p{p}_r2ccl", 0.0,
                            r2ccl_time(p, n, ell) / r.t0))
            rows.append(row(f"{tag}_p{p}_lb", 0.0, r.overhead_lb))
    # (b)/(d): message-size sweep at p=16 (element-time model is linear in
    # n; this verifies the linearity and pipeline amortization in k).
    for tag, ell in (("fig8b", 8 / 7), ("fig8d", 2.0)):
        p = 16
        for scale in (1, 4, 16):
            k = 32 * scale if scale <= 4 else 64
            n = k * (p - 1) * 64
            prof = BandwidthProfile.single_straggler(p, ell)
            r = score(prof, n, k)
            rows.append(row(f"{tag}_n{scale}x_optcc", wall(r),
                            r.overhead_optcc))
    # (e): sweep l at p=16.
    p, k = 16, 48
    n = k * (p - 1) * 64
    for ell in (1.0, 8 / 7, 4 / 3, 1.6, 2.0, 8 / 3, 4.0):
        prof = (BandwidthProfile.healthy(p) if ell == 1.0 else
                BandwidthProfile.single_straggler(p, ell))
        r = score(prof, n, k)
        rows.append(row(f"fig8e_l{ell:.2f}_optcc", wall(r), r.overhead_optcc))
        rows.append(row(f"fig8e_l{ell:.2f}_iccl", 0.0, ell))
        rows.append(row(f"fig8e_l{ell:.2f}_lb", 0.0,
                        lb.lb_single_straggler_tight(p, n, max(ell, 1.0))
                        / r.t0))
    return rows
