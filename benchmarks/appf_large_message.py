"""Appendix F: large-message stress (N = 8 GiB) - asymptotic per-byte rate.

Element-time is linear in n, so the interesting check is that the pipeline
amortization (k) keeps the *rate* at the asymptote for huge buffers.
Derived = completion / T0 at n = 2^31 elements (8 GiB of fp32 gradients).
Scenarios run through the sweep engine.
"""
from __future__ import annotations

from repro.core import BandwidthProfile
from benchmarks.common import row, score, wall


def run():
    rows = []
    n = 2 ** 31
    for p, ells, tag in ((64, [1.5], "appF_single"),
                         (64, [1.5, 2.0], "appF_multi")):
        k = 128
        prof = (BandwidthProfile.single_straggler(p, ells[0])
                if len(ells) == 1 else
                BandwidthProfile.multi_straggler(p, ells))
        r = score(prof, n, k)
        rows.append(row(f"{tag}_p{p}_8GiB_optcc", wall(r), r.overhead_optcc))
        rows.append(row(f"{tag}_p{p}_8GiB_lb", 0.0, r.overhead_lb))
    return rows
