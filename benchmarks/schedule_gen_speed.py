"""Section 4.3 claim: closed-form schedule generation, <1 ms at p=1024.

Measures (a) the O(pk) slot-descriptor path the claim refers to (batched
numpy array program; also CI-gated via schedgen_latency_ms_max in
ci/sweep_thresholds.json), (b) the columnar arrays path the sweep engine
simulates (same O(p^2 k) flow graph as Flow objects, built by vectorized
generators), and (c) full Flow-object materialization (the executor's
input). The descriptor path is reported per registered algorithm at
p=1024 so the <1 ms claim covers every topology the planner can emit,
not just the auto pick. Derived = wall milliseconds.
"""
from __future__ import annotations

import time

from repro.core import BandwidthProfile, make_plan, registry
from benchmarks.common import row


def run():
    rows = []
    for p in (64, 256, 1024):
        prof = BandwidthProfile.single_straggler(p, 1.5)
        n = (p - 1) * 4 * 16
        t0 = time.perf_counter()
        for _ in range(5):
            make_plan(prof, n, k=4, materialize=False)
        dt = (time.perf_counter() - t0) / 5
        rows.append(row(f"schedgen_descriptor_p{p}", dt, dt * 1e3,
                        "paper: <1ms at p=1024"))
    # Descriptor path per registered algorithm (flat p=1024 grid plus an
    # 8-GPU-server profile so `hierarchical` gets a row too).
    for prof in (BandwidthProfile.single_straggler(1024, 1.5),
                 BandwidthProfile.single_straggler(1024, 1.5, g=8)):
        g = prof.gpus_per_server
        n = (prof.p - 1) * 4 * 16
        for algo in registry.supported(prof):
            best = float("inf")
            for _ in range(5):
                t0 = time.perf_counter()
                make_plan(prof, n, k=4, materialize=False, algo=algo)
                best = min(best, time.perf_counter() - t0)
            rows.append(row(f"schedgen_descriptor_{algo}_p1024_g{g}",
                            best, best * 1e3,
                            "CI-gated: worst algo must stay <1ms"))
    for p in (64, 256, 1024):
        prof = BandwidthProfile.single_straggler(p, 1.5)
        n = (p - 1) * 4 * 16
        t0 = time.perf_counter()
        make_plan(prof, n, k=4, materialize="arrays")
        dt = time.perf_counter() - t0
        rows.append(row(f"schedgen_arrays_p{p}", dt, dt * 1e3,
                        "columnar flow graph (sweep hot path)"))
    for p in (64, 256):
        prof = BandwidthProfile.single_straggler(p, 1.5)
        n = (p - 1) * 4 * 16
        t0 = time.perf_counter()
        make_plan(prof, n, k=4, materialize=True)
        dt = time.perf_counter() - t0
        rows.append(row(f"schedgen_flows_p{p}", dt, dt * 1e3))
    return rows
