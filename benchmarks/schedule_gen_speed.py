"""Section 4.3 claim: closed-form schedule generation, <1 ms at p=1024.

Measures (a) the O(pk) slot-descriptor path the claim refers to, and
(b) full Flow-graph materialization (the simulator's input; O(p^2 k)).
Derived = wall milliseconds.
"""
from __future__ import annotations

import time

from repro.core import BandwidthProfile, make_plan
from benchmarks.common import row


def run():
    rows = []
    for p in (64, 256, 1024):
        prof = BandwidthProfile.single_straggler(p, 1.5)
        n = (p - 1) * 4 * 16
        t0 = time.perf_counter()
        for _ in range(5):
            make_plan(prof, n, k=4, materialize=False)
        dt = (time.perf_counter() - t0) / 5
        rows.append(row(f"schedgen_descriptor_p{p}", dt, dt * 1e3,
                        "paper: <1ms at p=1024"))
    for p in (64, 256):
        prof = BandwidthProfile.single_straggler(p, 1.5)
        n = (p - 1) * 4 * 16
        t0 = time.perf_counter()
        make_plan(prof, n, k=4, materialize=True)
        dt = time.perf_counter() - t0
        rows.append(row(f"schedgen_flows_p{p}", dt, dt * 1e3))
    return rows
