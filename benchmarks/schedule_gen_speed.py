"""Section 4.3 claim: closed-form schedule generation, <1 ms at p=1024.

Measures (a) the O(pk) slot-descriptor path the claim refers to (batched
numpy array program; also CI-gated via schedgen_latency_ms_max in
ci/sweep_thresholds.json), (b) the columnar arrays path the sweep engine
simulates (same O(p^2 k) flow graph as Flow objects, built by vectorized
generators), and (c) full Flow-object materialization (the executor's
input). Derived = wall milliseconds.
"""
from __future__ import annotations

import time

from repro.core import BandwidthProfile, make_plan
from benchmarks.common import row


def run():
    rows = []
    for p in (64, 256, 1024):
        prof = BandwidthProfile.single_straggler(p, 1.5)
        n = (p - 1) * 4 * 16
        t0 = time.perf_counter()
        for _ in range(5):
            make_plan(prof, n, k=4, materialize=False)
        dt = (time.perf_counter() - t0) / 5
        rows.append(row(f"schedgen_descriptor_p{p}", dt, dt * 1e3,
                        "paper: <1ms at p=1024"))
    for p in (64, 256, 1024):
        prof = BandwidthProfile.single_straggler(p, 1.5)
        n = (p - 1) * 4 * 16
        t0 = time.perf_counter()
        make_plan(prof, n, k=4, materialize="arrays")
        dt = time.perf_counter() - t0
        rows.append(row(f"schedgen_arrays_p{p}", dt, dt * 1e3,
                        "columnar flow graph (sweep hot path)"))
    for p in (64, 256):
        prof = BandwidthProfile.single_straggler(p, 1.5)
        n = (p - 1) * 4 * 16
        t0 = time.perf_counter()
        make_plan(prof, n, k=4, materialize=True)
        dt = time.perf_counter() - t0
        rows.append(row(f"schedgen_flows_p{p}", dt, dt * 1e3))
    return rows
