"""Shared helpers for the benchmark suite.

The figure/table modules are thin front-ends over the sweep engine
(repro.sweeps): each declares ScenarioSpecs and maps ScenarioResults to CSV
rows. Every benchmark returns rows (name, us_per_call, derived, note):
  us_per_call - wall time of the measured unit (schedule gen + simulate)
  derived     - the paper's metric: completion time normalized to the
                fault-free optimum T0 (NCCL_NoFailure), or as noted.
"""
from __future__ import annotations

from repro.core.model import BandwidthProfile
from repro.sweeps.engine import ScenarioResult, run_scenario
from repro.sweeps.scenarios import ScenarioSpec
from repro.sweeps.stats import summarize


def spec_for(profile: BandwidthProfile, n: int, k: int, name: str = "bench",
             family: str = "bench", simulate_ring: bool = False,
             fill_bubbles: bool = True) -> ScenarioSpec:
    """Wrap an explicit BandwidthProfile as a one-off sweep scenario."""
    return ScenarioSpec(name=name, family=family, p=profile.p, n=n, k=k,
                        slowdown=profile.slowdown,
                        gpus_per_server=profile.gpus_per_server,
                        nvlink_mult=profile.nvlink_mult,
                        fill_bubbles=fill_bubbles,
                        simulate_ring=simulate_ring)


def score(profile: BandwidthProfile, n: int, k: int,
          simulate_ring: bool = False) -> ScenarioResult:
    """Plan + simulate + score one profile through the sweep engine."""
    return run_scenario(spec_for(profile, n, k, simulate_ring=simulate_ring))


def wall(r: ScenarioResult) -> float:
    """Wall time of the measured unit: schedule gen + OptCC simulation
    (ring-baseline simulation time is tracked separately)."""
    return r.gen_seconds + r.sim_seconds


def row(name, wall_s, derived, note=""):
    return (name, wall_s * 1e6, derived, note)


def pct_rows(prefix, values, note=""):
    """One CSV row per summary statistic (p50/p99/max) of a sample."""
    return [row(f"{prefix}_{tag}", 0.0, v, note)
            for tag, v in summarize(values).items()]


def emit(rows):
    for name, us, derived, note in rows:
        print(f"{name},{us:.1f},{derived:.6g}{',' + note if note else ''}")
