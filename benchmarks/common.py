"""Shared helpers for the benchmark suite.

Every benchmark returns rows (name, us_per_call, derived, note):
  us_per_call - wall time of the measured unit (schedule gen + simulate)
  derived     - the paper's metric: completion time normalized to the
                fault-free optimum T0 (NCCL_NoFailure), or as noted.
"""
from __future__ import annotations

import time

from repro.core import (BandwidthProfile, optcc_schedule,
                        ring_allreduce_schedule, simulate)
from repro.core import lower_bounds as lb
from repro.core.baselines import r2ccl_time


def sim_optcc(profile, n, k, **kw):
    t0 = time.perf_counter()
    sched = optcc_schedule(profile, n, k, **kw)
    t = simulate(sched).makespan
    return t, time.perf_counter() - t0


def sim_ring(profile, n):
    t0 = time.perf_counter()
    t = simulate(ring_allreduce_schedule(profile, n)).makespan
    return t, time.perf_counter() - t0


def row(name, wall_s, derived, note=""):
    return (name, wall_s * 1e6, derived, note)


def emit(rows):
    for name, us, derived, note in rows:
        print(f"{name},{us:.1f},{derived:.6g}{',' + note if note else ''}")
