"""Unit tests for the bandwidth-bound discrete-event simulator."""
import numpy as np
import pytest

from repro.core.model import BandwidthProfile, Flow, Op, Schedule
from repro.core.simulator import simulate, simulate_many


def mk(profile, flows, n=100, nv=()):
    return Schedule(profile=profile, n=n, nic_flows=list(flows),
                    nvlink_flows=list(nv))


def F(fid, src, dst, size, deps=(), pri=None, release=0.0):
    return Flow(fid=fid, src=src, dst=dst, size=size, deps=tuple(deps),
                lo=0, hi=size, op=Op.STORE, key=("t", fid), pri=pri,
                release=release)


def test_single_flow_healthy():
    prof = BandwidthProfile.healthy(2)
    res = simulate(mk(prof, [F(0, 0, 1, 100)]))
    assert res.makespan == pytest.approx(100.0)


def test_slow_endpoint_throttles():
    """A flow incident to a straggler takes l * size (either endpoint)."""
    prof = BandwidthProfile.single_straggler(3, 2.5)
    res = simulate(mk(prof, [F(0, 0, 1, 100)]))   # from straggler
    assert res.makespan == pytest.approx(250.0)
    res = simulate(mk(prof, [F(0, 1, 0, 100)]))   # to straggler
    assert res.makespan == pytest.approx(250.0)
    res = simulate(mk(prof, [F(0, 1, 2, 100)]))   # healthy pair
    assert res.makespan == pytest.approx(100.0)


def test_port_exclusivity_serializes():
    """Two flows into one recv port may not overlap (Section 4.1)."""
    prof = BandwidthProfile.healthy(3)
    res = simulate(mk(prof, [F(0, 0, 2, 100), F(1, 1, 2, 100)]))
    assert res.makespan == pytest.approx(200.0)
    # distinct ports -> parallel
    res = simulate(mk(prof, [F(0, 0, 1, 100), F(1, 1, 2, 100)]))
    assert res.makespan == pytest.approx(100.0)


def test_full_duplex():
    """Send and recv ports are independent (full duplex NICs)."""
    prof = BandwidthProfile.healthy(2)
    res = simulate(mk(prof, [F(0, 0, 1, 100), F(1, 1, 0, 100)]))
    assert res.makespan == pytest.approx(100.0)


def test_dependencies_chain():
    prof = BandwidthProfile.healthy(4)
    res = simulate(mk(prof, [F(0, 0, 1, 50), F(1, 1, 2, 50, deps=[0]),
                             F(2, 2, 3, 50, deps=[1])]))
    assert res.makespan == pytest.approx(150.0)


def test_priority_orders_contention():
    prof = BandwidthProfile.healthy(3)
    # Lower pri wins the contended port even with higher fid.
    flows = [F(0, 0, 2, 100, pri=10.0), F(1, 1, 2, 100, pri=1.0)]
    res = simulate(mk(prof, flows))
    assert res.start[1] == 0.0 and res.start[0] == pytest.approx(100.0)


def test_release_gates_start():
    prof = BandwidthProfile.healthy(2)
    res = simulate(mk(prof, [F(0, 0, 1, 10, release=500.0)]))
    assert res.start[0] == pytest.approx(500.0)
    assert res.makespan == pytest.approx(510.0)


def test_work_conserving_overtaking():
    """A low-priority ready flow runs when the high-priority one is blocked
    on its other port - this packs bubble-filling flows into gaps."""
    prof = BandwidthProfile.healthy(4)
    flows = [
        F(0, 1, 2, 100),                  # occupies 1->2
        F(1, 1, 3, 100, deps=[0]),        # wants port 1 send, later
        F(2, 0, 3, 50),                   # lower priority by fid, ready now
    ]
    res = simulate(mk(prof, flows))
    assert res.start[2] == 0.0            # overtakes into 3's recv port


def test_nvlink_rate_and_separation():
    """NVLink ports run at (g-1)x NIC rate and don't contend with NIC."""
    prof = BandwidthProfile.healthy(4, g=4)
    nic = [F(0, 0, 1, 90)]
    nv = [Flow(fid=1, src=0, dst=1, size=90, deps=(), lo=0, hi=90,
               op=Op.STORE, key=("nv",))]
    res = simulate(mk(prof, nic, nv=nv))
    assert res.finish[1] == pytest.approx(30.0)   # 90/(g-1)
    assert res.finish[0] == pytest.approx(90.0)   # unaffected by NVLink


def test_deadlock_detection():
    prof = BandwidthProfile.healthy(2)
    # Circular dependency -> deadlock must raise, not hang.
    flows = [F(0, 0, 1, 10, deps=[1]), F(1, 1, 0, 10, deps=[0])]
    with pytest.raises(RuntimeError, match="deadlock"):
        simulate(mk(prof, flows))


def test_determinism():
    """Same schedule -> identical result (paper: SimAI is deterministic)."""
    from repro.core.schedule import optcc_schedule
    prof = BandwidthProfile.single_straggler(8, 1.5)
    s = optcc_schedule(prof, 7 * 8 * 16, 8)
    r1, r2 = simulate(s), simulate(s)
    assert r1.makespan == r2.makespan
    assert r1.start == r2.start


def test_simulate_many_matches_simulate():
    from repro.core.ring import ring_allreduce_schedule
    from repro.core.schedule import optcc_schedule
    scheds = [
        optcc_schedule(BandwidthProfile.single_straggler(8, 1.5), 7 * 8 * 16, 8),
        ring_allreduce_schedule(BandwidthProfile.healthy(8), 800),
        optcc_schedule(BandwidthProfile.multi_straggler(8, [2.0, 1.5]),
                       6 * 4 * 16, 4),
    ]
    serial = simulate_many(scheds, workers=0)
    assert [r.makespan for r in serial] == \
        [simulate(s).makespan for s in scheds]
    pooled = simulate_many(scheds, workers=2)
    assert [r.makespan for r in pooled] == [r.makespan for r in serial]


def test_utilization_accounting():
    prof = BandwidthProfile.healthy(2)
    res = simulate(mk(prof, [F(0, 0, 1, 60), F(1, 0, 1, 40, deps=[0])]))
    assert res.utilization("nic", 0, "s") == pytest.approx(1.0)
    assert res.utilization("nic", 1, "r") == pytest.approx(1.0)
    assert res.utilization("nic", 1, "s") == 0.0
