"""Online planner: failure event -> schedule plan, fast enough for inline use."""
import time

import pytest

from repro.core import BandwidthProfile, make_plan, simulate


def test_plan_healthy_is_ring():
    plan = make_plan(BandwidthProfile.healthy(8), n=800)
    assert plan.algo == "ring"
    assert plan.predicted_overhead <= 1.2


def test_plan_degraded_is_optcc():
    plan = make_plan(BandwidthProfile.single_straggler(8, 1.5), n=7 * 16 * 20,
                     k=16)
    assert plan.algo == "optcc-single"
    assert plan.lower_bound <= plan.predicted_time
    t = simulate(plan.schedule).makespan
    assert t >= plan.lower_bound * 0.999


def test_plan_overhead_small_for_half_bandwidth():
    """Paper abstract: l <= 2 => overhead O(1/p)."""
    plan = make_plan(BandwidthProfile.single_straggler(128, 2.0),
                     n=127 * 16 * 10, k=16)
    assert plan.predicted_overhead < 1.13


def test_generation_speed_p1024():
    """Section 4.3 claims O(pk) schedule generation, < 1 ms at p=1024.
    The O(pk) artifact is the slot descriptor (per-hop flows are implied by
    the closed-form chain rules); materializing every flow object for the
    simulator is O(p^2 k) and benchmarked separately."""
    prof = BandwidthProfile.single_straggler(1024, 1.5)
    t0 = time.perf_counter()
    plan = make_plan(prof, n=1023 * 4 * 10, k=4, materialize=False)
    dt = time.perf_counter() - t0
    assert len(plan.descriptor["slots"]) == 1023 * 4
    assert plan.schedule is None
    assert dt < 1.0  # descriptor path; paper claims ~1 ms, allow CI slack


def test_plan_multi_variants():
    plan = make_plan(BandwidthProfile.multi_straggler(12, [1.5, 2.0]),
                     n=10 * 4 * 10, k=4)
    assert plan.algo == "optcc-multi"
    plan = make_plan(BandwidthProfile.single_straggler(8, 2.0, g=2),
                     n=2 * 4 * 7 * 10, k=4)
    assert plan.algo == "optcc-multigpu"
