"""Online planner: failure event -> schedule plan, fast enough for inline use."""
import time

import pytest

from repro.core import BandwidthProfile, make_plan, simulate


def test_plan_healthy_is_ring():
    plan = make_plan(BandwidthProfile.healthy(8), n=800)
    assert plan.algo == "ring"
    assert plan.predicted_overhead <= 1.2


def test_plan_degraded_is_optcc():
    plan = make_plan(BandwidthProfile.single_straggler(8, 1.5), n=7 * 16 * 20,
                     k=16)
    assert plan.algo == "optcc-single"
    assert plan.lower_bound <= plan.predicted_time
    t = simulate(plan.schedule).makespan
    assert t >= plan.lower_bound * 0.999


def test_plan_overhead_small_for_half_bandwidth():
    """Paper abstract: l <= 2 => overhead O(1/p) - asymptotically in k.
    The calibrated prediction charges the true ~5(p-1)s pipeline head, which
    at k=16 is still a 29% overhead; by k=64 it has amortized below 8%.
    (The pre-calibration formula under-counted the head and made this pass
    at k=16.)"""
    plan = make_plan(BandwidthProfile.single_straggler(128, 2.0),
                     n=127 * 64 * 10, k=64)
    assert plan.predicted_overhead < 1.13


def test_generation_speed_p1024():
    """Section 4.3 claims O(pk) schedule generation, < 1 ms at p=1024.
    The O(pk) artifact is the slot descriptor (per-hop flows are implied by
    the closed-form chain rules); materializing every flow object for the
    simulator is O(p^2 k) and benchmarked separately."""
    prof = BandwidthProfile.single_straggler(1024, 1.5)
    t0 = time.perf_counter()
    plan = make_plan(prof, n=1023 * 4 * 10, k=4, materialize=False)
    dt = time.perf_counter() - t0
    assert len(plan.descriptor["slots"]) == 1023 * 4
    assert plan.schedule is None
    assert dt < 1.0  # descriptor path; paper claims ~1 ms, allow CI slack


def test_descriptor_slots_nonnegative():
    """All slot offsets are valid times - in particular for small n, where
    the old raw -2/-4 constants (elements, not element-times) drove the
    S2/S3 slots negative."""
    from repro.core.planner import plan_descriptor
    for n in (8, 64, 1024):
        desc = plan_descriptor(BandwidthProfile.single_straggler(8, 1.5),
                               n=n, k=2)
        for key, (nu, *times) in desc["slots"].items():
            assert all(t >= 0.0 for t in times), (n, key, times)


def test_descriptor_linear_in_n():
    """Slot offsets are element-times: doubling n doubles every offset
    exactly (unit consistency; the raw -2/-4 constants broke this)."""
    from repro.core.planner import plan_descriptor
    prof = BandwidthProfile.single_straggler(16, 1.3)
    d1 = plan_descriptor(prof, n=15 * 4 * 12, k=4)
    d2 = plan_descriptor(prof, n=2 * 15 * 4 * 12, k=4)
    assert d1["slots"].keys() == d2["slots"].keys()
    for key, (nu1, *t1) in d1["slots"].items():
        nu2, *t2 = d2["slots"][key]
        assert nu1 == nu2
        for a, b in zip(t1, t2):
            assert b == pytest.approx(2.0 * a, rel=1e-12)


def test_plan_multi_variants():
    plan = make_plan(BandwidthProfile.multi_straggler(12, [1.5, 2.0]),
                     n=10 * 4 * 10, k=4)
    assert plan.algo == "optcc-multi"
    plan = make_plan(BandwidthProfile.single_straggler(8, 2.0, g=2),
                     n=2 * 4 * 7 * 10, k=4)
    assert plan.algo == "optcc-multigpu"
