"""Data-level correctness: every schedule computes a full AllReduce.

The executor moves real numpy payloads through the exact flow graph the
simulator times; a schedule passes iff every rank ends with sum_i x_i.
Covers ring (healthy + degraded), OptCC single straggler (both the exact
slotted generator and the legacy pattern-alternating one, with and without
bubble filling), multi-straggler, and multi-GPU/server schedules - plus
every algorithm in `core.registry`, driven through the registry itself so
a newly registered topology is covered without touching this file.
"""
import numpy as np
import pytest

from repro.core import BandwidthProfile, make_plan, registry, verify_allreduce
from repro.core.ring import ring_allreduce_schedule
from repro.core.schedule import (optcc_multi_gpu_schedule,
                                 optcc_multi_schedule, optcc_schedule,
                                 optcc_single_schedule)

RNG = np.random.default_rng(42)


def rand_x(p, n):
    return RNG.standard_normal((p, n))


@pytest.mark.parametrize("p", [2, 3, 5, 8, 17])
def test_ring_healthy(p):
    n = 16 * p
    sched = ring_allreduce_schedule(BandwidthProfile.healthy(p), n)
    verify_allreduce(sched, rand_x(p, n))


@pytest.mark.parametrize("p,ell", [(4, 1.5), (8, 2.0), (9, 3.0)])
def test_ring_degraded_iccl(p, ell):
    n = 8 * p
    prof = BandwidthProfile.single_straggler(p, ell, straggler=p // 2)
    verify_allreduce(ring_allreduce_schedule(prof, n), rand_x(p, n))


@pytest.mark.parametrize("p", [5, 8, 16])
@pytest.mark.parametrize("ell", [1.14, 1.5, 2.0, 3.0])
@pytest.mark.parametrize("k", [1, 4, 7])
def test_optcc_single_slotted(p, ell, k):
    n = max(k * (p - 1) * 8, 64)
    prof = BandwidthProfile.single_straggler(p, ell)
    verify_allreduce(optcc_single_schedule(prof, n, k), rand_x(p, n))


@pytest.mark.parametrize("straggler", [0, 3, 7])
def test_optcc_single_straggler_position(straggler):
    p, k, ell = 8, 4, 1.5
    n = k * (p - 1) * 10
    prof = BandwidthProfile.single_straggler(p, ell, straggler=straggler)
    verify_allreduce(optcc_single_schedule(prof, n, k), rand_x(p, n))


@pytest.mark.parametrize("fill", [True, False])
def test_optcc_single_fill_toggle(fill):
    p, k, ell = 9, 6, 1.33
    n = k * (p - 1) * 12
    prof = BandwidthProfile.single_straggler(p, ell)
    verify_allreduce(
        optcc_single_schedule(prof, n, k, fill_bubbles=fill), rand_x(p, n))


@pytest.mark.parametrize("p", [5, 8])
@pytest.mark.parametrize("ell", [1.5, 2.5])
def test_optcc_single_legacy_patterns(p, ell):
    """The pattern-alternating (ordering A/B) legacy generator."""
    k, n = 8, 8 * 8 * (p - 1)
    prof = BandwidthProfile.single_straggler(p, ell)
    sched = optcc_single_schedule(prof, n, k, alternate_orderings=True)
    verify_allreduce(sched, rand_x(p, n))


def test_optcc_small_p_fallback():
    """p=3,4 route to the legacy generator and stay correct."""
    for p in (3, 4):
        prof = BandwidthProfile.single_straggler(p, 1.7)
        verify_allreduce(optcc_single_schedule(prof, 60, 3), rand_x(p, 60))


@pytest.mark.parametrize("ells", [[1.5, 1.2], [2.0, 2.0], [3.0, 1.14, 1.7]])
@pytest.mark.parametrize("p", [8, 16])
def test_optcc_multi_straggler(p, ells):
    k = 4
    n = k * (p - len(ells)) * 10
    prof = BandwidthProfile.multi_straggler(p, ells)
    verify_allreduce(optcc_multi_schedule(prof, n, k), rand_x(p, n))


def test_optcc_multi_straggler_positions():
    p, k = 12, 3
    n = k * 9 * 8
    prof = BandwidthProfile.multi_straggler(p, [1.5, 2.5, 1.2],
                                            stragglers=[1, 5, 11])
    verify_allreduce(optcc_multi_schedule(prof, n, k), rand_x(p, n))


@pytest.mark.parametrize("g", [2, 4])
@pytest.mark.parametrize("q", [4, 6])
@pytest.mark.parametrize("ell", [1.5, 2.0, 3.0])
def test_optcc_multi_gpu(g, q, ell):
    p, k = g * q, 4
    n = g * k * (q - 1) * 6
    prof = BandwidthProfile.single_straggler(p, ell, straggler=1, g=g)
    assert prof.num_servers == q
    verify_allreduce(optcc_multi_gpu_schedule(prof, n, k), rand_x(p, n))


def test_dispatcher_selects_variants():
    n, k = 480, 4
    s = optcc_schedule(BandwidthProfile.healthy(8), n, k)
    assert s.meta["algo"] == "ring"
    s = optcc_schedule(BandwidthProfile.single_straggler(8, 1.5), n, k)
    assert s.meta["algo"] == "optcc-single"
    s = optcc_schedule(BandwidthProfile.multi_straggler(8, [1.5, 1.2]), n, k)
    assert s.meta["algo"] == "optcc-multi"
    s = optcc_schedule(
        BandwidthProfile.single_straggler(8, 2.0, g=2), n, k)
    assert s.meta["algo"] == "optcc-multigpu"


# Every profile regime a registry entry may claim to support; each entry is
# exercised on each profile it supports (p=12 factors as 3x4 for torus2d).
REGISTRY_PROFILES = [
    BandwidthProfile.healthy(12),
    BandwidthProfile.single_straggler(12, 2.0, straggler=5),
    BandwidthProfile.multi_straggler(12, [1.5, 3.0]),
    BandwidthProfile.healthy(12, g=3),
    BandwidthProfile.single_straggler(12, 2.0, straggler=1, g=3),
]


@pytest.mark.parametrize("name", registry.names())
def test_every_registered_algo_correct(name):
    """Registry-driven: each registered algorithm computes a full AllReduce
    on every supported profile - no per-algorithm special cases."""
    entry = registry.get(name)
    checked = 0
    for prof in REGISTRY_PROFILES:
        if not entry.supports(prof):
            continue
        k = 4
        g = prof.gpus_per_server
        n = g * k * max(prof.p // g - 1, 1) * 6 + 5      # ragged on purpose
        plan = make_plan(prof, n, k=k, algo=name)
        verify_allreduce(plan.schedule, rand_x(prof.p, n))
        checked += 1
    assert checked, f"no profile in the pool exercises {name!r}"


def test_executor_rejects_nontopological():
    from repro.core.model import Flow, Op, Schedule
    from repro.core.executor import execute
    prof = BandwidthProfile.healthy(2)
    flows = [Flow(fid=0, src=0, dst=1, size=4, deps=(1,), lo=0, hi=4,
                  op=Op.STORE, key=("x",)),
             Flow(fid=1, src=1, dst=0, size=4, deps=(), lo=0, hi=4,
                  op=Op.STORE, key=("x",))]
    sched = Schedule(profile=prof, n=4, nic_flows=flows)
    with pytest.raises(ValueError):
        execute(sched, np.ones((2, 4)))
