"""Validate the closed-form lower bounds (Theorems 1, 2, 3, 6, 13).

Each theorem's closed form is the solution of a min-max program
min_{x,y>=1} max{...}; we check the algebra by brute-force numeric
minimization over the (x, y) grid, and check the structural properties the
paper states (consistency between theorems, regime transition points,
asymptotics in Table 1).
"""
import numpy as np
import pytest

from repro.core import lower_bounds as lb


def brute_single(p, n, ell, tight):
    best = np.inf
    for z in np.linspace(1.0, 5.0, 20001):
        if tight:
            val = max(2 + (ell - 2) * z / (p - 1), ell * z)
        else:
            val = max(2 - z / (p - 1), ell * z)
        best = min(best, val)
    return best * n


@pytest.mark.parametrize("p", [3, 5, 16, 128])
@pytest.mark.parametrize("ell", [1.01, 1.14, 1.5, 1.99, 2.0, 2.5, 4.0])
def test_theorem1_matches_minmax(p, ell):
    n = 1000.0
    assert lb.lb_single_straggler(p, n, ell) == pytest.approx(
        brute_single(p, n, ell, tight=False), rel=1e-3)


@pytest.mark.parametrize("p", [3, 5, 16, 128])
@pytest.mark.parametrize("ell", [1.01, 1.14, 1.5, 1.99, 2.0, 2.5, 4.0])
def test_theorem6_matches_minmax(p, ell):
    n = 1000.0
    assert lb.lb_single_straggler_tight(p, n, ell) == pytest.approx(
        brute_single(p, n, ell, tight=True), rel=1e-3)


def test_theorem6_tighter_than_theorem1():
    for p in (4, 16, 64):
        for ell in (1.1, 1.5, 1.9, 2.5):
            assert lb.lb_single_straggler_tight(p, 1.0, ell) >= \
                lb.lb_single_straggler(p, 1.0, ell) - 1e-12


def test_theorem2_reduces_to_theorem1():
    for p in (5, 32):
        for ell in (1.2, 1.8, 3.0):
            assert lb.lb_multi_straggler(p, 7.0, [ell]) == pytest.approx(
                lb.lb_single_straggler(p, 7.0, ell))


def test_theorem3_reduces_to_theorem1():
    for p in (5, 32):
        for ell in (1.2, 1.8, 3.0):
            assert lb.lb_multi_gpu(p, 7.0, ell, g=1) == pytest.approx(
                lb.lb_single_straggler(p, 7.0, ell))
    # and Theorem 13 -> Theorem 6 at g=1
    for ell in (1.2, 3.0):
        assert lb.lb_multi_gpu_tight(16, 7.0, ell, g=1) == pytest.approx(
            lb.lb_single_straggler_tight(16, 7.0, ell))


def test_fault_free_t0():
    assert lb.t0_fault_free(8, 800.0) == pytest.approx(2 * 7 * 100.0)
    assert lb.t0_fault_free(8, 800.0, g=2) == pytest.approx(7 * 100.0 * 2 / 2)


def test_regime_transition():
    """Table 1: at l >= 2 the straggler-link branch (l n) dominates."""
    p, n = 16, 1.0
    for ell in (2.0, 2.4, 5.0):
        assert lb.lb_single_straggler_tight(p, n, ell) == pytest.approx(
            ell * n)
    # Below the transition, the healthy-side branch dominates.
    assert lb.lb_single_straggler_tight(p, n, 1.1) > 1.1 * n


def test_overhead_vanishes_large_p():
    """Takeaway of Section 3: for l < 2, LB/T0 -> 1 as p grows (O(1/p))."""
    ell = 1.9
    overheads = []
    for p in (8, 64, 512, 4096):
        ratio = lb.lb_single_straggler_tight(p, 1.0, ell) / \
            lb.t0_fault_free(p, 1.0)
        overheads.append(ratio - 1.0)
    for a, b in zip(overheads, overheads[1:]):
        assert b < a / 4  # shrinks ~linearly in 1/p (factor-8 p steps)
    assert overheads[-1] < 0.001


def test_paper_claim_less_than_1pct_at_128():
    """Abstract: 'less than 1% at p=128 GPUs' when l <= 2."""
    for ell in (1.14, 1.5, 2.0):
        over = lb.lb_single_straggler_tight(128, 1.0, ell) / \
            lb.t0_fault_free(128, 1.0) - 1.0
        assert over < 0.01


def test_multi_straggler_bound_monotone():
    n = 1.0
    base = lb.lb_multi_straggler(64, n, [1.5])
    more = lb.lb_multi_straggler(64, n, [1.5, 1.5, 1.5])
    assert more >= base


def test_achieved_times_dominate_bounds():
    """Closed-form achieved times (Sec 4.3/App C/D/E) >= lower bounds."""
    for p in (8, 16, 64):
        for ell in (1.14, 1.5, 2.0, 3.0):
            for k in (8, 64):
                t = lb.optcc_time_single(p, 1.0, ell, k)
                assert t >= lb.lb_single_straggler_tight(p, 1.0, ell) - 1e-9
    for p in (16, 64):
        t = lb.optcc_time_multi(p, 1.0, [2.5, 1.5], 64)
        assert t >= lb.lb_multi_straggler(p, 1.0, [2.5, 1.5]) - 1e-9
    for g in (2, 4, 8):
        p = 8 * g
        for ell in (1.5, 2.0, 3.0):
            t = lb.optcc_time_multi_gpu(p, 1.0, ell, g, 64)
            assert t >= lb.lb_multi_gpu_tight(p, 1.0, ell, g) - 1e-9


def test_optcc_single_asymptotically_optimal():
    """Appendix C: T/LB -> 1 (exactly, for all p) as k -> inf."""
    for p in (8, 32):
        for ell in (1.14, 1.5, 1.99, 2.0, 3.0):
            t_inf = lb.optcc_time_asymptotic(p, 1.0, [ell])
            bound = lb.lb_single_straggler_tight(p, 1.0, ell)
            assert t_inf == pytest.approx(bound, rel=1e-9)
