"""Observability layer: telemetry must not perturb timings, critical-path
attribution must account for the entire makespan, traces must be valid and
port-consistent, and the artifact/threshold plumbing must gate on stages."""
import copy
import json

import numpy as np
import pytest

from repro import obs
from repro.core.model import STAGE_ID, BandwidthProfile
from repro.core.ring import ring_allreduce_schedule
from repro.core.schedule import (optcc_multi_gpu_schedule,
                                 optcc_multi_schedule, optcc_schedule,
                                 optcc_single_schedule)
from repro.core.schedule_vec import optcc_schedule_arrays
from repro.core.simulator import simulate, simulate_reference
from repro.sweeps import (build_artifact, check_thresholds, run_scenario,
                          run_sweep, smoke_grid, validate_artifact)
from repro.sweeps.artifact import load_artifact, write_artifact

PROFILES = [
    pytest.param(BandwidthProfile.healthy(8), id="healthy-ring"),
    pytest.param(BandwidthProfile.single_straggler(8, 1.75, 3), id="single-fill"),
    pytest.param(BandwidthProfile.single_straggler(8, 3.0, 3), id="single-l3"),
    pytest.param(BandwidthProfile.multi_straggler(16, (2.0, 3.0), (1, 9)),
                 id="multi"),
    pytest.param(BandwidthProfile.single_straggler(16, 2.5, 1, g=4),
                 id="multigpu"),
]

# Every 11th smoke scenario: all five families, a few seconds of CPU.
SUB = smoke_grid(seed=0)[::11]


# ----------------------------------------------------------------------------
# telemetry is free: timings identical on and off
# ----------------------------------------------------------------------------

def test_telemetry_does_not_change_timings_on_grid():
    off = run_sweep(SUB, workers=0, measure_latency=False)
    on = run_sweep(SUB, workers=0, measure_latency=False, telemetry=True)
    for a, b in zip(off, on):
        assert a.t_optcc == b.t_optcc, b.spec.name       # IEEE-754 equal
        assert a.t_ring == b.t_ring, b.spec.name
        assert a.stage_breakdown is None
        assert b.stage_breakdown


@pytest.mark.parametrize("profile", PROFILES)
def test_simulate_telemetry_flag(profile):
    sch = optcc_schedule_arrays(profile, 65536, 4)
    r_off = simulate(sch)
    r_on = simulate(sch, telemetry=True)
    assert r_off.telemetry is None
    assert r_on.telemetry is not None
    assert r_off.makespan == r_on.makespan
    # identical per-flow times too, not just the max
    assert r_off.start == r_on.start and r_off.finish == r_on.finish


# ----------------------------------------------------------------------------
# exact attribution
# ----------------------------------------------------------------------------

@pytest.mark.parametrize("profile", PROFILES)
def test_stage_breakdown_sums_to_makespan(profile):
    sch = optcc_schedule_arrays(profile, 65536, 4)
    res = simulate(sch, telemetry=True)
    bd = obs.stage_breakdown(res.telemetry)
    total = sum(bd.values())
    assert total == pytest.approx(res.makespan, rel=1e-9)
    assert all(v > 0 for v in bd.values())


@pytest.mark.parametrize("profile", PROFILES)
def test_stage_breakdown_reference_path(profile):
    """The scalar oracle's telemetry obeys the same exactness invariant."""
    sch = optcc_schedule(profile, 65536, 4)
    res = simulate_reference(sch, telemetry=True)
    bd = obs.stage_breakdown(res.telemetry)
    assert sum(bd.values()) == pytest.approx(res.makespan, rel=1e-9)


def test_critical_path_tiles_the_makespan():
    sch = optcc_schedule_arrays(
        BandwidthProfile.single_straggler(8, 1.75, 3), 65536, 4)
    res = simulate(sch, telemetry=True)
    segments, gaps = obs.critical_path(res.telemetry)
    # Segments and gaps, merged by time, must cover [0, makespan] seamlessly.
    pieces = sorted(
        [(s["start"], s["finish"]) for s in segments]
        + [(g["t0"], g["t1"]) for g in gaps])
    assert pieces[0][0] == 0.0
    assert pieces[-1][1] == res.makespan
    for (a0, a1), (b0, b1) in zip(pieces, pieces[1:]):
        assert a1 == b0, "overlap or hole in the critical-path tiling"


# ----------------------------------------------------------------------------
# stage tagging
# ----------------------------------------------------------------------------

@pytest.mark.parametrize("profile", PROFILES)
def test_vec_and_scalar_stage_ids_agree(profile):
    scalar = optcc_schedule(profile, 65536, 4)
    vec = optcc_schedule_arrays(profile, 65536, 4)
    a = scalar.meta["stage_ids"]
    b = vec.meta["stage_ids"]
    assert len(a) == scalar.num_flows == vec.num_flows == len(b)
    assert np.array_equal(np.asarray(a), np.asarray(b))


def test_stage_vocabulary_by_family():
    ring = ring_allreduce_schedule(BandwidthProfile.healthy(8), 4096)
    assert set(np.unique(ring.meta["stage_ids"])) == \
        {STAGE_ID["RS"], STAGE_ID["AG"], STAGE_ID["SELF"]}
    single = optcc_single_schedule(
        BandwidthProfile.single_straggler(8, 1.75, 3), 65536, 4)
    assert {STAGE_ID["S1"], STAGE_ID["S2"], STAGE_ID["S3"],
            STAGE_ID["S4"]} <= set(np.unique(single.meta["stage_ids"]))
    multi = optcc_multi_schedule(
        BandwidthProfile.multi_straggler(8, (2.0, 3.0)), 65536, 4)
    assert {STAGE_ID["S1"], STAGE_ID["S2"], STAGE_ID["S3"],
            STAGE_ID["S4"]} <= set(np.unique(multi.meta["stage_ids"]))
    mg = optcc_multi_gpu_schedule(
        BandwidthProfile.single_straggler(8, 2.5, 1, g=2), 65536, 4)
    tags = set(np.unique(mg.meta["stage_ids"]))
    assert {STAGE_ID["N1"], STAGE_ID["N2"], STAGE_ID["N3"],
            STAGE_ID["N4"]} <= tags


# ----------------------------------------------------------------------------
# chrome trace
# ----------------------------------------------------------------------------

@pytest.mark.parametrize("profile", PROFILES)
def test_chrome_trace_roundtrip_and_port_exclusivity(profile, tmp_path):
    sch = optcc_schedule_arrays(profile, 65536, 4)
    res = simulate(sch, telemetry=True)
    path = tmp_path / "trace.json"
    obs.write_chrome_trace(res.telemetry, str(path))
    tr = json.loads(path.read_text())          # valid JSON round-trip
    evs = tr["traceEvents"]
    assert any(e["ph"] == "M" for e in evs)
    # Within every (pid, tid) lane, complete events must not overlap and
    # must be monotone once sorted by ts - ports are exclusive resources.
    lanes = {}
    for e in evs:
        if e["ph"] != "X" or e["cat"] != "flow":
            continue
        assert e["dur"] > 0
        lanes.setdefault((e["pid"], e["tid"]), []).append(
            (e["ts"], e["ts"] + e["dur"]))
    assert lanes
    for lane, iv in lanes.items():
        iv.sort()
        for (a0, a1), (b0, b1) in zip(iv, iv[1:]):
            assert b0 >= a1, f"overlapping events in lane {lane}"
    # One critical-path lane whose slices sum to the makespan.
    cp = [e for e in evs if e["ph"] == "X" and e["cat"] == "critical"]
    assert sum(e["dur"] for e in cp) == pytest.approx(res.makespan,
                                                      rel=1e-9)


# ----------------------------------------------------------------------------
# artifact schema v2 + gating
# ----------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tel_artifact():
    results = run_sweep(SUB, workers=0, measure_latency=False,
                        telemetry=True)
    return build_artifact(results, profile="smoke/11", seed=0,
                          deterministic=True, telemetry=True)


def test_telemetry_artifact_validates(tel_artifact):
    assert tel_artifact["telemetry"] is True
    assert validate_artifact(tel_artifact) == []
    for rec in tel_artifact["scenarios"]:
        assert rec["gen_ms"] is None and rec["sim_ms"] is None
        # Replay rows attribute the no-replan run (their t_optcc is the
        # re-planning controller's adopted makespan).
        ref = rec.get("t_noreplan", rec["t_optcc"])
        assert sum(rec["stage_breakdown"].values()) == \
            pytest.approx(ref, rel=1e-6)
    assert tel_artifact["summary"]["overall"]["stages"]


def test_validator_catches_bad_stage_sum(tel_artifact):
    bad = copy.deepcopy(tel_artifact)
    first_stage = next(iter(bad["scenarios"][0]["stage_breakdown"]))
    bad["scenarios"][0]["stage_breakdown"][first_stage] *= 2.0
    assert any("stage_breakdown sums" in e for e in validate_artifact(bad))
    bad = copy.deepcopy(tel_artifact)
    del bad["scenarios"][0]["stage_breakdown"]
    assert any("lacks stage_breakdown" in e for e in validate_artifact(bad))


def test_stage_thresholds_gate(tel_artifact):
    base = {"schema": "optcc-sweep-thresholds/1"}
    loose = dict(base, stage_overhead_p99_max={"S1": 100.0})
    assert check_thresholds(tel_artifact, loose) == []
    tight = dict(base, stage_overhead_p99_max={"S1": 1e-6})
    assert any("stage S1" in f for f in check_thresholds(tel_artifact, tight))
    ghost = dict(base, stage_overhead_p99_max={"NOPE": 1.0})
    assert any("absent" in f for f in check_thresholds(tel_artifact, ghost))
    # a stage gate against a telemetry-less artifact must fail, not skip
    results = run_sweep(SUB[:3], workers=0, measure_latency=False)
    plain = build_artifact(results, profile="x", seed=0, deterministic=True)
    assert any("no stage telemetry" in f
               for f in check_thresholds(plain, loose))


def test_v1_artifact_migration(tmp_path):
    results = run_sweep(SUB[:3], workers=0, measure_latency=False)
    art = build_artifact(results, profile="x", seed=0, deterministic=True)
    # Regress the artifact to v1 on-disk form: schema tag, no telemetry
    # flag, zeros instead of nulls for unmeasured wall-clock fields.
    art["schema"] = "optcc-sweep/1"
    del art["telemetry"]
    for rec in art["scenarios"]:
        rec["gen_ms"] = rec["sim_ms"] = 0.0
    for stats in [art["summary"]["overall"],
                  *art["summary"]["by_family"].values()]:
        stats["gen_ms_p50"] = stats["gen_ms_p99"] = 0.0
    path = tmp_path / "v1.json"
    write_artifact(art, str(path))
    migrated = load_artifact(str(path))
    # v1 chains through v2, v3 and v4 up to the current schema.
    assert migrated["schema"] == "optcc-sweep/5"
    assert migrated["telemetry"] is False
    assert migrated["retries"] is None
    assert migrated["scenarios"][0]["gen_ms"] is None
    assert migrated["summary"]["overall"]["gen_ms_p99"] is None
    assert validate_artifact(migrated) == []


def test_run_scenario_breakdown_matches_direct():
    """The sweep's stage_breakdown is the same attribution `obs` computes
    on the scenario's plan, not a reimplementation."""
    spec = next(s for s in SUB if s.family == "single")
    r = run_scenario(spec, measure_latency=False, telemetry=True)
    from repro.core.planner import make_plan
    plan = make_plan(spec.profile(), spec.n, k=spec.k,
                     fill_bubbles=spec.fill_bubbles, materialize="arrays")
    res = simulate(plan.schedule, telemetry=True)
    assert r.stage_breakdown == obs.stage_breakdown(res.telemetry)
    assert sum(r.stage_breakdown.values()) == pytest.approx(r.t_optcc,
                                                            rel=1e-9)
