"""Per-architecture smoke tests: reduced config of the same family, one
forward/train step on CPU, asserting output shapes and no NaNs.
(Full configs are exercised only by the dry-run - no allocation here.)
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import build_model
from repro.optim import AdamWConfig
from repro.optim.schedules import constant
from repro.train import init_train_state, make_gspmd_train_step
from jax.sharding import Mesh

RNG = np.random.default_rng(0)


def make_batch(cfg, B=2, S=24):
    toks = jnp.asarray(RNG.integers(0, cfg.vocab_size, (B, S)))
    batch = {"tokens": toks, "labels": toks}
    if cfg.family == "whisper":
        batch["frames"] = jnp.asarray(RNG.standard_normal(
            (B, cfg.n_audio_frames, cfg.d_model)), jnp.float32)
    if cfg.family == "vlm":
        batch["prefix_embeds"] = jnp.asarray(RNG.standard_normal(
            (B, cfg.n_patch_tokens, cfg.d_model)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_spec(arch):
    cfg = get_config(arch)
    spec = {
        "qwen3-1.7b": (28, 2048, 16, 8, 6144, 151936),
        "gemma3-27b": (62, 5376, 32, 16, 21504, 262144),
        "minicpm-2b": (40, 2304, 36, 36, 5760, 122753),
        "internlm2-1.8b": (24, 2048, 16, 8, 8192, 92544),
        "rwkv6-7b": (32, 4096, 64, 64, 14336, 65536),
        "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
        "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32064),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
        "whisper-base": (6, 512, 8, 8, 2048, 51865),
        "qwen2-vl-2b": (28, 1536, 12, 2, 8960, 151936),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == spec
    # family-specific markers from the assignment
    if arch == "arctic-480b":
        assert cfg.n_experts == 128 and cfg.top_k == 2 and cfg.moe_dense_ff
    if arch == "phi3.5-moe-42b-a6.6b":
        assert cfg.n_experts == 16 and cfg.top_k == 2
    if arch == "qwen3-1.7b":
        assert cfg.qk_norm
    if arch == "gemma3-27b":
        assert cfg.global_every == 6  # 5 local : 1 global
    if arch == "hymba-1.5b":
        assert cfg.ssm_state == 16
    if arch == "qwen2-vl-2b":
        assert cfg.mrope
    if arch == "whisper-base":
        assert cfg.encoder_layers == 6


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    batch = make_batch(cfg)
    params = jax.jit(model.init)(jax.random.PRNGKey(0))
    loss = jax.jit(model.loss)(params, batch)
    assert np.isfinite(float(loss)), f"{arch}: loss={loss}"

    opt = AdamWConfig()
    state = init_train_state(model, opt)
    mesh = Mesh(np.array(jax.devices()), ("data",))
    step = jax.jit(make_gspmd_train_step(model, mesh, opt, constant(1e-3)))
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    for leaf in jax.tree.leaves(state.params):
        assert np.isfinite(np.asarray(leaf, np.float32)).all(), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_decode(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    B, S = 2, 16
    batch = make_batch(cfg, B, S)
    batch.pop("labels")
    params = jax.jit(model.init)(jax.random.PRNGKey(0))
    logits, _ = jax.jit(model.prefill)(params, batch)
    assert logits.shape == (B, cfg.vocab_size)
    cache = model.init_cache(B, S + 4)
    lg, cache2 = jax.jit(model.decode_step)(
        params, cache, batch["tokens"][:, :1], jnp.int32(S))
    assert lg.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(lg)).all(), arch
