"""JAX collective correctness on 8 forced host devices.

Runs in a subprocess because --xla_force_host_platform_device_count must be
set before jax initializes, and the rest of the suite must see 1 device.
"""
import os
import pathlib
import subprocess
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent


@pytest.mark.slow
def test_collectives_on_8_devices():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, str(REPO / "tests" / "multidev_driver.py")],
        capture_output=True, text=True, env=env, timeout=600)
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    assert "ALL-OK" in proc.stdout


@pytest.mark.slow
def test_elastic_node_loss_rescale():
    """Train on 8 virtual devices, lose half at step 4, continue on 4."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch",
         "qwen3-1.7b", "--smoke", "--steps", "8", "--lose-node-at", "4",
         "--seq-len", "32", "--log-every", "2"],
        capture_output=True, text=True, env=env, timeout=900, cwd=REPO)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "NODE LOSS - resumed on 4 devices" in proc.stdout
    assert "done" in proc.stdout


@pytest.mark.slow
def test_failure_injection_path():
    """The driver detects the injected NIC loss, re-plans with OptCC,
    and recovers to psum on repair - full paper loop in one run."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch",
         "qwen3-1.7b", "--smoke", "--steps", "9", "--fail-at", "3",
         "--repair-at", "6", "--seq-len", "32", "--log-every", "3"],
        capture_output=True, text=True, env=env, timeout=900, cwd=REPO)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "DEGRADED" in proc.stdout and "optcc-single" in proc.stdout
    assert "REPAIRED; back to native psum" in proc.stdout
