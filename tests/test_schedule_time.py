"""Timing validation: simulated schedules vs the calibrated closed forms.

lower_bounds.optcc_time is calibrated against the simulator (constants
documented in that module); the contract these tests pin down:
  * ring on healthy profile: exactly 2(p-1)n/p (flat ring over NICs).
  * OptCC single straggler, l >= 2, p >= 5: bit-exact closed form; l < 2
    within ~3.5% (greedy bubble filling shifts a few slots).
  * every regime (healthy / single / multi / multi-GPU at minimal NVLink):
    |sim/pred - 1| <= 10% at k=4 (test_predicted_time_within_10pct).
  * DGX-realistic 12x NVLink is deliberately NOT separately calibrated: the
    multi-GPU form assumes the paper's minimal (g-1)x provisioning and
    conservatively over-predicts when NVLink is faster, so the 12x case is
    excluded from the 10% gate and pinned one-sided instead.
"""
import dataclasses

import pytest

from repro.core import BandwidthProfile, simulate
from repro.core import lower_bounds as lb
from repro.core.ring import ring_allreduce_schedule
from repro.core.schedule import optcc_schedule


def sim_time(profile, n, k=None, **kw):
    if k is None:
        sched = ring_allreduce_schedule(profile, n)
    else:
        sched = optcc_schedule(profile, n, k, **kw)
    return simulate(sched).makespan


@pytest.mark.parametrize("p", [4, 8, 16])
def test_ring_healthy_achieves_t0(p):
    n = 240 * p
    t = sim_time(BandwidthProfile.healthy(p), n)
    assert t == pytest.approx(lb.t0_fault_free(p, n), rel=1e-9)


@pytest.mark.parametrize("p,ell", [(8, 1.5), (8, 2.0), (16, 3.0)])
def test_ring_degraded_pays_ell(p, ell):
    """ICCL: the unmodified ring pays >= ~l x T0 (asymptotically)."""
    n = 480 * p
    t = sim_time(BandwidthProfile.single_straggler(p, ell), n)
    assert t >= 0.95 * ell * lb.t0_fault_free(p, n)
    assert t <= 1.35 * ell * lb.t0_fault_free(p, n)


@pytest.mark.parametrize("ell", [1.14, 1.5, 2.0, 3.0])
@pytest.mark.parametrize("p", [8, 16])
def test_optcc_single_matches_closed_form(p, ell):
    k = 32
    n = k * (p - 1) * 100
    t = sim_time(BandwidthProfile.single_straggler(p, ell), n, k)
    pred = lb.optcc_time(p, n, [ell], k)
    assert t >= lb.lower_bound(p, n, [ell]) * 0.999
    assert t <= 1.03 * pred   # calibrated form; l >= 2 is bit-exact


def test_optcc_single_converges_with_k():
    """sim/pred -> 1 as k grows (zero steady-state bubbles)."""
    p, ell = 16, 1.5
    ratios = []
    for k in (16, 64, 192):
        n = k * (p - 1) * 100
        t = sim_time(BandwidthProfile.single_straggler(p, ell), n, k)
        ratios.append(t / lb.optcc_time(p, n, [ell], k))
    assert ratios[2] < ratios[0]
    assert ratios[2] < 1.035


def test_optcc_beats_iccl_and_r2ccl():
    """Headline claim: OptCC close to fault-free; baselines far."""
    from repro.core.baselines import r2ccl_time
    p, ell, k = 32, 1.5, 96
    n = k * (p - 1) * 100
    t0 = lb.t0_fault_free(p, n)
    t = sim_time(BandwidthProfile.single_straggler(p, ell), n, k)
    assert t / t0 < 1.10                      # paper: 2-6% band
    assert ell * t0 / t0 == pytest.approx(1.5)   # ICCL pays l
    assert t < 0.87 * r2ccl_time(p, n, ell)      # beats SOTA clearly


def test_optcc_fill_beats_nofill():
    """Appendix C: bubble filling strictly reduces time for l < 2."""
    p, ell, k = 16, 1.5, 64
    n = k * (p - 1) * 100
    prof = BandwidthProfile.single_straggler(p, ell)
    t_fill = sim_time(prof, n, k, fill_bubbles=True)
    t_nofill = sim_time(prof, n, k, fill_bubbles=False)
    assert t_fill < t_nofill


def test_optcc_ell_ge_2_linear_in_ell():
    """For l >= 2 the straggler link binds: T ~ l n (Eq. 1)."""
    p, k = 16, 32
    n = k * (p - 1) * 100
    t3 = sim_time(BandwidthProfile.single_straggler(p, 3.0), n, k)
    t6 = sim_time(BandwidthProfile.single_straggler(p, 6.0), n, k)
    assert t6 / t3 == pytest.approx(2.0, rel=0.06)


@pytest.mark.parametrize("ells", [[1.33, 1.14], [2.0, 1.33]])
def test_optcc_multi_straggler_time(ells):
    p, k = 16, 32
    n = k * (p - len(ells)) * 100
    prof = BandwidthProfile.multi_straggler(p, ells)
    t = sim_time(prof, n, k)
    assert t >= lb.lb_multi_straggler(p, n, ells) * 0.999
    assert t <= 1.05 * lb.optcc_time_multi(p, n, ells, k)


def test_optcc_multi_straggler_beats_degraded_ring():
    p, k = 16, 32
    ells = [1.5, 1.5]
    n = k * (p - 2) * 100
    prof = BandwidthProfile.multi_straggler(p, ells)
    t = sim_time(prof, n, k)
    t_ring = simulate(ring_allreduce_schedule(prof, n)).makespan
    assert t < 0.85 * t_ring


@pytest.mark.parametrize("ell", [1.14, 2.0, 3.0])
def test_optcc_multi_gpu_time(ell):
    g, q, k = 4, 8, 16
    p = g * q
    n = g * k * (q - 1) * 64
    prof = BandwidthProfile.single_straggler(p, ell, g=g)
    t = sim_time(prof, n, k)
    pred = lb.optcc_time_multi_gpu(p, n, ell, g, k)
    assert t >= lb.lb_multi_gpu_tight(p, n, ell, g) * 0.999
    assert t <= 1.45 * pred   # zero-slack NVLink under (g-1)x provisioning


@pytest.mark.parametrize("ell", [1.14, 2.0, 3.0])
def test_optcc_multi_gpu_time_dgx_nvlink(ell):
    """With DGX-realistic NVLink (12x NIC), E.4 is met within ~15%."""
    g, q, k = 4, 8, 16
    p = g * q
    n = g * k * (q - 1) * 64
    prof = dataclasses.replace(
        BandwidthProfile.single_straggler(p, ell, g=g), nvlink_mult=12.0)
    t = sim_time(prof, n, k)
    assert t <= 1.15 * lb.optcc_time_multi_gpu(p, n, ell, g, k)


# One case per calibrated regime, k=4, biased toward the worst residuals
# found during calibration (mgpu g=8 q=4 and g=4 l=4/3 sit ~9.4% off; the
# rest are well inside). nvlink_mult=12 is excluded by design - see module
# docstring.
TEN_PCT_CASES = [
    ("healthy", 8, 1, None),
    ("healthy", 32, 2, None),
    ("single", 4, 1, 1.5),
    ("single", 8, 1, 8.0 / 7.0),
    ("single", 16, 1, 2.0),
    ("single", 32, 1, 4.0 / 3.0),
    ("single", 64, 1, 4.0),
    ("multi", 16, 1, (2.0, 2.0)),
    ("multi", 16, 1, (1.5, 1.3)),
    ("multi", 32, 1, (2.5, 2.5, 2.5)),
    ("multi", 8, 1, (8.0, 2.0)),
    ("mgpu", 8, 2, 2.0),
    ("mgpu", 16, 2, 8.0 / 7.0),
    ("mgpu", 32, 4, 4.0 / 3.0),
    ("mgpu", 16, 4, 4.0),
    ("mgpu", 32, 8, 4.0 / 3.0),
    ("mgpu", 64, 8, 2.0),
]


@pytest.mark.parametrize("regime,p,g,ells", TEN_PCT_CASES)
def test_predicted_time_within_10pct(regime, p, g, ells):
    """lower_bounds.optcc_time is operator-grade: within 10% of the
    simulator at k=4 in every calibrated regime (and never below the lower
    bound). Targets the OptCC generators directly - the planner may fall
    back to the ring when OptCC's fill overhead loses at shallow k, which
    would mask the calibration being checked here."""
    from repro.core.schedule_vec import optcc_schedule_arrays, ring_arrays
    k = 4
    if regime == "healthy":
        prof = BandwidthProfile.healthy(p, g=g)
        n = 4 * p * 48
        t = simulate(ring_arrays(prof, n)).makespan
        pred = lb.optcc_time(prof.p, n, [], k, g)
        lbound = lb.lower_bound(prof.p, n, [], g)
    else:
        if regime == "single":
            prof = BandwidthProfile.single_straggler(p, ells,
                                                     straggler=p // 2)
            n = k * (p - 1) * 48
            pred_ells = [ells]
        elif regime == "multi":
            prof = BandwidthProfile.multi_straggler(p, list(ells))
            n = k * (p - len(ells)) * 48
            pred_ells = list(ells)
        else:
            q = p // g
            prof = BandwidthProfile.single_straggler(p, ells, straggler=1,
                                                     g=g)
            n = g * k * (q - 1) * 48
            pred_ells = [ells]
        t = simulate(optcc_schedule_arrays(prof, n, k)).makespan
        pred = lb.optcc_time(prof.p, n, pred_ells, k, g)
        lbound = lb.lower_bound(prof.p, n, pred_ells, g)
    assert t >= lbound * (1 - 1e-9)
    assert abs(t / pred - 1.0) <= 0.10


@pytest.mark.parametrize("p", [8, 16])
def test_ring_degraded_monotone_in_ell(p):
    """FIFO send sequencing makes the degraded ring convoy-stable: makespan
    is non-decreasing in the straggler severity (greedy dispatch without the
    FIFO deps showed jitter where a *slower* link finished *earlier*)."""
    n = 480 * p
    prev = 0.0
    for ell in (1.0, 1.2, 1.5, 2.0, 2.5, 3.0, 4.0, 6.0):
        t = sim_time(BandwidthProfile.single_straggler(p, ell), n)
        assert t >= prev - 1e-9, f"ring time dropped at ell={ell}"
        prev = t


def test_no_port_overlap_invariant():
    """The simulator never books two flows on one port simultaneously."""
    p, ell, k = 8, 1.5, 8
    n = k * (p - 1) * 40
    sched = optcc_schedule(BandwidthProfile.single_straggler(p, ell), n, k)
    res = simulate(sched)
    intervals = {}
    for f in sched.nic_flows:
        if f.size <= 0:
            continue
        s, e = res.start[f.fid], res.finish[f.fid]
        intervals.setdefault(("s", f.src), []).append((s, e))
        intervals.setdefault(("r", f.dst), []).append((s, e))
    for port, iv in intervals.items():
        iv.sort()
        for (s1, e1), (s2, e2) in zip(iv, iv[1:]):
            assert e1 <= s2 + 1e-9, f"overlap on port {port}"
