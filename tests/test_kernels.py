"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode.

interpret=True executes the kernel body on CPU - validating the block
decomposition, index maps, masking and online-softmax algebra; the Mosaic
lowering itself requires a real TPU (documented in DESIGN.md).
"""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need the hypothesis extra")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.kernels.chunk_reduce.ops import chunk_reduce
from repro.kernels.chunk_reduce.ref import chunk_reduce_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.wkv.ops import wkv
from repro.kernels.wkv.ref import wkv_ref

RNG = np.random.default_rng(7)


# ----------------------------------------------------------------------------
# chunk_reduce
# ----------------------------------------------------------------------------

@pytest.mark.parametrize("w", [1, 2, 7, 16])
@pytest.mark.parametrize("n", [128, 1000, 4096, 5001])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_chunk_reduce_sweep(w, n, dtype):
    x = jnp.asarray(RNG.standard_normal((w, n)), dtype)
    out = chunk_reduce(x, block=1024, interpret=True)
    ref = chunk_reduce_ref(x)
    tol = 1e-6 if dtype == np.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_chunk_reduce_fp32_accumulation():
    """bf16 inputs must accumulate in fp32 (W large, catastrophic in bf16)."""
    w, n = 16, 512
    x = jnp.full((w, n), 1.0 + 1e-3, jnp.bfloat16)
    out = chunk_reduce(x, block=256, interpret=True, out_dtype=jnp.float32)
    expect = np.float32(w) * np.asarray(x[0], np.float32)
    np.testing.assert_allclose(np.asarray(out), expect, rtol=1e-3)


@settings(max_examples=10, deadline=None)
@given(w=st.integers(1, 8), n=st.integers(1, 2000),
       block=st.sampled_from([128, 256, 1024]))
def test_chunk_reduce_property(w, n, block):
    x = jnp.asarray(np.random.default_rng(n).standard_normal((w, n)),
                    jnp.float32)
    out = chunk_reduce(x, block=block, interpret=True)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(chunk_reduce_ref(x)),
                               rtol=1e-5, atol=1e-5)


# ----------------------------------------------------------------------------
# flash attention
# ----------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [
    # (B, Sq, Skv, H, KV, hd)
    (1, 32, 32, 2, 2, 16),
    (2, 64, 64, 4, 2, 32),     # GQA
    (1, 48, 48, 4, 1, 32),     # MQA
    (2, 40, 40, 2, 2, 8),      # non-multiple of block
])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_flash_attention_sweep(shape, dtype):
    B, Sq, Skv, H, KV, hd = shape
    q = jnp.asarray(RNG.standard_normal((B, Sq, H, hd)), dtype)
    k = jnp.asarray(RNG.standard_normal((B, Skv, KV, hd)), dtype)
    v = jnp.asarray(RNG.standard_normal((B, Skv, KV, hd)), dtype)
    out = flash_attention(q, k, v, causal=True, bq=16, bkv=16,
                          interpret=True)
    ref = flash_attention_ref(q, k, v, causal=True)
    tol = 2e-5 if dtype == np.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("window", [8, 24, 1000])
def test_flash_attention_window(window):
    B, S, H, KV, hd = 2, 64, 4, 2, 16
    q = jnp.asarray(RNG.standard_normal((B, S, H, hd)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((B, S, KV, hd)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((B, S, KV, hd)), jnp.float32)
    out = flash_attention(q, k, v, causal=True, window=window,
                          bq=16, bkv=16, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)


def test_flash_attention_noncausal():
    B, S, H, KV, hd = 1, 32, 2, 2, 16
    q = jnp.asarray(RNG.standard_normal((B, S, H, hd)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((B, S, KV, hd)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((B, S, KV, hd)), jnp.float32)
    out = flash_attention(q, k, v, causal=False, bq=16, bkv=16,
                          interpret=True)
    ref = flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)


def test_flash_matches_model_chunked_path():
    """The kernel and the model's chunked-jnp path agree (same oracle)."""
    from repro.models.attention import chunked_attention
    B, S, H, KV, hd = 1, 64, 4, 2, 16
    q = jnp.asarray(RNG.standard_normal((B, S, H, hd)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((B, S, KV, hd)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((B, S, KV, hd)), jnp.float32)
    a = flash_attention(q, k, v, causal=True, bq=16, bkv=16,
                        interpret=True)
    b = chunked_attention(q, k, v, causal=True, q_chunk=16, kv_chunk=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=3e-5, atol=3e-5)


# ----------------------------------------------------------------------------
# wkv
# ----------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(1, 16, 2, 8), (2, 33, 3, 16),
                                   (1, 64, 1, 32)])
def test_wkv_sweep(shape):
    B, S, H, hd = shape
    rng = np.random.default_rng(sum(shape))
    r, k, v = [jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
               for _ in range(3)]
    w = jnp.asarray(rng.uniform(0.2, 0.99, (B, S, H, hd)), jnp.float32)
    u = jnp.asarray(rng.standard_normal((H, hd)), jnp.float32)
    out, st = wkv(r, k, v, w, u, interpret=True)
    ro, rs = wkv_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ro),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(st), np.asarray(rs),
                               rtol=1e-5, atol=1e-5)


def test_wkv_state_chaining():
    """Processing a sequence in two kernel calls chained through the state
    equals one call - the property the serving path relies on."""
    B, S, H, hd = 1, 32, 2, 8
    rng = np.random.default_rng(0)
    r, k, v = [jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
               for _ in range(3)]
    w = jnp.asarray(rng.uniform(0.5, 0.99, (B, S, H, hd)), jnp.float32)
    u = jnp.asarray(rng.standard_normal((H, hd)), jnp.float32)
    full, st_full = wkv(r, k, v, w, u, interpret=True)
    h1, st1 = wkv(r[:, :16], k[:, :16], v[:, :16], w[:, :16], u,
                  interpret=True)
    h2, st2 = wkv(r[:, 16:], k[:, 16:], v[:, 16:], w[:, 16:], u,
                  state0=st1, interpret=True)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(full[:, 16:]),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(st2), np.asarray(st_full),
                               rtol=1e-5, atol=1e-5)
