"""Sweep engine: grid determinism, artifact schema, and the paper's
statistical claim (OptCC no worse than the degraded ring wherever the worst
NIC keeps >= 50% bandwidth) on a CI-sized sub-grid."""
import copy
import json

import pytest

from repro.sweeps import (SCHEMA, build_artifact, canonical_bytes,
                          check_thresholds, full_grid, run_scenario,
                          run_sweep, sanity_check, smoke_grid,
                          validate_artifact)
from repro.sweeps.artifact import percentile
from repro.sweeps.scenarios import ScenarioSpec

# A thinned slice of the smoke grid: every 6th scenario keeps all five
# families represented while staying a few seconds of CPU.
SUB = smoke_grid(seed=0)[::6]


@pytest.fixture(scope="module")
def sub_results():
    return run_sweep(SUB, workers=0, measure_latency=False)


@pytest.fixture(scope="module")
def sub_artifact(sub_results):
    return build_artifact(sub_results, profile="smoke/6", seed=0,
                          deterministic=True)


# ----------------------------------------------------------------------------
# grids
# ----------------------------------------------------------------------------

def test_grids_are_deterministic():
    a, b = smoke_grid(seed=0), smoke_grid(seed=0)
    assert a == b
    assert smoke_grid(seed=1) != a          # seed actually feeds the tail
    assert full_grid(seed=0) == full_grid(seed=0)


def test_smoke_grid_size_and_diversity():
    specs = smoke_grid(seed=0)
    assert len(specs) >= 200
    fams = {s.family for s in specs}
    assert {"healthy", "single", "multi", "multigpu", "correlated",
            "replay", "detection", "topology"} <= fams
    # Distinct scenarios: no two specs share the same physical setup
    # (replay specs differ by their failure timeline, detection specs by
    # their detector/controller parameters, topology specs by the
    # explicitly requested algorithm too).
    keys = {(s.p, s.n, s.k, s.slowdown, s.gpus_per_server, s.nvlink_mult,
             s.events, s.detection, s.algo)
            for s in specs}
    assert len(keys) == len(specs)
    # The nightly grid keeps every family too (dedup must not fold the
    # correlated-fault block into multigpu).
    full_fams = {s.family for s in full_grid(seed=0)}
    assert {"healthy", "single", "multi", "multigpu", "correlated",
            "replay", "detection", "topology"} <= full_fams


def test_heterogeneous_ells_present():
    hetero = [s for s in smoke_grid(seed=0) if s.family == "multi"
              and len(set(s.slowdown[i] for i in s.stragglers)) > 1]
    assert hetero


# ----------------------------------------------------------------------------
# engine + invariants
# ----------------------------------------------------------------------------

def test_sweep_results_dominate_lower_bound(sub_results):
    assert sanity_check(sub_results) == []
    for r in sub_results:
        assert r.t_optcc >= r.lower_bound * (1 - 1e-9), r.spec.name
        # Note: lower_bound >= t0 only holds for g == 1; the multi-GPU
        # bound references q = p/g servers, so it can sit below the p-NIC
        # fault-free optimum (the seed's fig10 LB rows are < 1.0 too).
        assert r.t0 > 0 and r.lower_bound > 0


def test_optcc_beats_degraded_ring_for_ell_le_2(sub_results):
    """The paper's headline regime: worst NIC keeps >= 50% bandwidth =>
    OptCC overhead <= degraded-ring (ICCL) overhead, scenario by scenario."""
    checked = 0
    for r in sub_results:
        if r.t_ring is None or not r.spec.stragglers:
            continue
        if r.spec.max_ell <= 2.0:
            assert r.overhead_optcc <= r.overhead_ring * (1 + 1e-9), \
                (r.spec.name, r.overhead_optcc, r.overhead_ring)
            checked += 1
    assert checked >= 10                    # the regime is actually covered


def test_parallel_matches_serial():
    specs = SUB[:6]
    serial = run_sweep(specs, workers=0, measure_latency=False)
    par = run_sweep(specs, workers=2, measure_latency=False)
    for a, b in zip(serial, par):
        assert a.t_optcc == b.t_optcc
        assert a.t_ring == b.t_ring
        assert a.lower_bound == b.lower_bound


def test_single_scenario_healthy_ring_reuse():
    spec = ScenarioSpec(name="h", family="healthy", p=8, n=8 * 64, k=4,
                        slowdown=(1.0,) * 8)
    r = run_scenario(spec, measure_latency=False)
    assert r.algo == "ring"
    assert r.t_ring == r.t_optcc            # healthy plan *is* the ring


# ----------------------------------------------------------------------------
# artifact
# ----------------------------------------------------------------------------

def test_artifact_schema_valid(sub_artifact):
    assert sub_artifact["schema"] == SCHEMA
    assert validate_artifact(sub_artifact) == []
    # round-trip through JSON keeps it valid (what CI consumes)
    assert validate_artifact(json.loads(canonical_bytes(sub_artifact))) == []


def test_artifact_byte_identical_across_runs(sub_artifact):
    results2 = run_sweep(SUB, workers=0, measure_latency=False)
    art2 = build_artifact(results2, profile="smoke/6", seed=0,
                          deterministic=True)
    assert canonical_bytes(sub_artifact) == canonical_bytes(art2)


def test_validate_catches_corruption(sub_artifact):
    bad = copy.deepcopy(sub_artifact)
    bad["scenarios"][0]["t_optcc"] = bad["scenarios"][0]["lower_bound"] * 0.5
    assert any("lower bound" in e for e in validate_artifact(bad))
    bad = copy.deepcopy(sub_artifact)
    del bad["scenarios"][0]["overhead_optcc"]
    assert validate_artifact(bad)
    bad = copy.deepcopy(sub_artifact)
    bad["scenario_count"] += 1
    assert validate_artifact(bad)
    bad = copy.deepcopy(sub_artifact)
    bad["schema"] = "optcc-sweep/0"
    assert validate_artifact(bad)


def test_thresholds_gate(sub_artifact):
    ths = {"schema": "optcc-sweep-thresholds/1",
           "overhead_optcc_p99_max": 100.0,
           "optcc_vs_lb_max_max": 100.0,
           "min_scenarios": 1}
    assert check_thresholds(sub_artifact, ths) == []
    tight = dict(ths, overhead_optcc_p99_max=1.0)
    assert any("p99" in f for f in check_thresholds(sub_artifact, tight))
    many = dict(ths, min_scenarios=10 ** 6)
    assert check_thresholds(sub_artifact, many)
    assert check_thresholds(sub_artifact, {"schema": "nope"})


def test_percentile():
    xs = list(range(1, 101))
    assert percentile(xs, 50) == pytest.approx(50.5)
    assert percentile(xs, 99) == pytest.approx(99.01)
    assert percentile([7.0], 99) == 7.0
    assert percentile(xs, 0) == 1 and percentile(xs, 100) == 100


def test_topology_rows_scored_and_excluded_from_overall(sub_artifact):
    """Topology rows carry requested_algo/t_auto/overhead_vs_auto, feed
    summary.by_algo, and are excluded from summary.overall (they are
    deliberately suboptimal baselines; optcc-sweep/5 docstring)."""
    topo = [r for r in sub_artifact["scenarios"]
            if r["family"] == "topology"]
    assert topo                              # the sub-grid kept the family
    for r in topo:
        assert r["requested_algo"] in ("hierarchical", "dbtree", "torus2d")
        assert r["t_auto"] > 0
        assert r["overhead_vs_auto"] == pytest.approx(
            r["t_optcc"] / r["t_auto"])
    assert set(sub_artifact["summary"]["by_algo"]) == \
        {r["requested_algo"] for r in topo}
    n_auto = len(sub_artifact["scenarios"]) - len(topo)
    assert sub_artifact["summary"]["overall"]["count"] == n_auto
    fam_stats = sub_artifact["summary"]["by_family"]["topology"]
    assert fam_stats["count"] == len(topo)
    assert "overhead_vs_auto_p99" in fam_stats


def test_validate_catches_topology_corruption(sub_artifact):
    bad = copy.deepcopy(sub_artifact)
    topo = next(r for r in bad["scenarios"] if r["family"] == "topology")
    del topo["t_auto"]
    assert any("t_auto" in e for e in validate_artifact(bad))
    bad = copy.deepcopy(sub_artifact)
    other = next(r for r in bad["scenarios"] if r["family"] != "topology")
    other["t_auto"] = 1.0
    assert any("non-topology" in e for e in validate_artifact(bad))
    bad = copy.deepcopy(sub_artifact)
    del bad["summary"]["by_algo"]
    assert any("by_algo" in e for e in validate_artifact(bad))


# ----------------------------------------------------------------------------
# schema migration chain (v1 -> v2 -> v3 -> v4 -> v5)
# ----------------------------------------------------------------------------

def _v1_artifact(deterministic: bool = True) -> dict:
    """A minimal but structurally honest optcc-sweep/1 artifact: v1 wrote
    0.0 (not null) for unmeasured wall-clock fields, predates telemetry,
    the replay/detection families, and the retry counter."""
    summary_stats = {
        "count": 1,
        "overhead_optcc_p50": 1.5, "overhead_optcc_p99": 1.5,
        "overhead_optcc_max": 1.5,
        "optcc_vs_lb_p50": 1.0, "optcc_vs_lb_p99": 1.0,
        "optcc_vs_lb_max": 1.0,
        "gen_ms_p50": 0.0, "gen_ms_p99": 0.0,
    }
    return {
        "schema": "optcc-sweep/1",
        "profile": "smoke", "seed": 0,
        "deterministic": deterministic,
        "schedgen_latency_ms": None,
        "scenario_count": 1,
        "summary": {"overall": dict(summary_stats), "by_family": {}},
        "scenarios": [{
            "name": "s", "family": "single", "algo": "optcc",
            "p": 8, "k": 4, "n": 448, "gpus_per_server": 1,
            "nvlink_mult": None, "num_flows": 10,
            "stragglers": [0], "ells": [1.5],
            "t0": 100.0, "lower_bound": 120.0, "t_optcc": 150.0,
            "t_ring": 160.0, "t_predicted": 150.0,
            "overhead_optcc": 1.5, "overhead_ring": 1.6,
            "overhead_lb": 1.2, "optcc_vs_lb": 1.25,
            "gen_ms": 0.0, "sim_ms": 0.0,
        }],
    }


def _load_from(tmp_path, obj) -> dict:
    from repro.sweeps import load_artifact, write_artifact
    path = str(tmp_path / "a.json")
    write_artifact(obj, path)
    return load_artifact(path)


def test_migration_v1_to_current(tmp_path):
    got = _load_from(tmp_path, _v1_artifact())
    assert got["schema"] == SCHEMA
    assert got["telemetry"] is False             # v1 -> v2
    assert got["retries"] is None                # v3 -> v4: unknown, not 0
    # v1 -> v2 on a deterministic artifact: 0.0 placeholders become null.
    assert got["scenarios"][0]["gen_ms"] is None
    assert got["scenarios"][0]["sim_ms"] is None
    assert got["summary"]["overall"]["gen_ms_p50"] is None
    assert validate_artifact(got) == []


def test_migration_v1_measured_keeps_latencies(tmp_path):
    got = _load_from(tmp_path, _v1_artifact(deterministic=False))
    assert got["schema"] == SCHEMA
    assert got["scenarios"][0]["gen_ms"] == 0.0  # measured zeros survive
    assert validate_artifact(got) == []


def test_migration_v1_empty_families_and_scenarios(tmp_path):
    obj = _v1_artifact()
    obj["scenarios"] = []
    obj["scenario_count"] = 0
    obj["summary"]["by_family"] = {}
    got = _load_from(tmp_path, obj)              # must not crash
    assert got["schema"] == SCHEMA and got["retries"] is None


def test_migration_v1_missing_optional_keys(tmp_path):
    obj = _v1_artifact()
    del obj["schedgen_latency_ms"]               # optional in v1 writers
    got = _load_from(tmp_path, obj)
    assert got["schema"] == SCHEMA
    assert validate_artifact(got) == []


def test_migration_v3_to_current(tmp_path, sub_artifact):
    # A v3 artifact predates both the retry counter and the topology
    # family: strip them and walk the whole v3 -> v4 -> v5 chain.
    obj = copy.deepcopy(sub_artifact)
    obj["schema"] = "optcc-sweep/3"
    del obj["retries"]
    obj["scenarios"] = [r for r in obj["scenarios"]
                        if r["family"] != "topology"]
    obj["scenario_count"] = len(obj["scenarios"])
    del obj["summary"]["by_algo"]
    got = _load_from(tmp_path, obj)
    assert got["schema"] == SCHEMA
    assert got["retries"] is None
    assert validate_artifact(got) == []
    # A current artifact round-trips untouched: retries stays 0.
    got2 = _load_from(tmp_path, sub_artifact)
    assert got2["retries"] == 0


def test_migration_v4_to_v5(tmp_path, sub_artifact):
    """v4 -> v5 is additive: a v4 artifact (no topology rows, no by_algo)
    migrates to a valid v5 artifact with only the tag moving."""
    obj = copy.deepcopy(sub_artifact)
    obj["schema"] = "optcc-sweep/4"
    obj["scenarios"] = [r for r in obj["scenarios"]
                        if r["family"] != "topology"]
    obj["scenario_count"] = len(obj["scenarios"])
    del obj["summary"]["by_algo"]
    got = _load_from(tmp_path, obj)
    assert got["schema"] == SCHEMA
    assert validate_artifact(got) == []
    assert "by_algo" not in got["summary"]


# ----------------------------------------------------------------------------
# hardened worker fan-out
# ----------------------------------------------------------------------------

def test_run_sweep_records_zero_retries_on_clean_run():
    stats = {}
    res = run_sweep(SUB[:8], workers=2, measure_latency=False, stats=stats)
    assert stats["retries"] == 0
    assert [r.spec.name for r in res] == [s.name for s in SUB[:8]]
    # Parallel fan-out returns bit-identical results to serial.
    ser = run_sweep(SUB[:8], workers=0, measure_latency=False)
    assert [r.t_optcc for r in res] == [r.t_optcc for r in ser]


def test_run_sweep_serial_ignores_pool_machinery():
    stats = {}
    res = run_sweep(SUB[:2], workers=0, measure_latency=False, stats=stats)
    assert len(res) == 2 and stats["retries"] == 0
