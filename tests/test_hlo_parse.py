"""Unit tests for the loop-trip-aware HLO analyzer (roofline inputs)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.hlo_parse import analyze_hlo


def compile_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_dot_flops_exact():
    a = jnp.zeros((64, 128), jnp.float32)
    b = jnp.zeros((128, 32), jnp.float32)
    text = compile_text(lambda a, b: a @ b, a, b)
    got = analyze_hlo(text).flops
    assert got == pytest.approx(2 * 64 * 128 * 32, rel=0.01)


def test_scan_trip_multiplier():
    w = jnp.zeros((32, 32), jnp.float32)
    x = jnp.zeros((8, 32), jnp.float32)

    def f(w, x):
        def body(c, _):
            return jnp.tanh(c @ w), None
        c, _ = jax.lax.scan(body, x, None, length=7)
        return c

    text = compile_text(f, w, x)
    a = analyze_hlo(text)
    assert a.flops == pytest.approx(7 * 2 * 8 * 32 * 32, rel=0.05)
    assert 7 in a.trip_counts.values()


def test_nested_scan_multiplies():
    w = jnp.zeros((16, 16), jnp.float32)
    x = jnp.zeros((4, 16), jnp.float32)

    def f(w, x):
        def inner(c, _):
            return c @ w, None

        def outer(c, _):
            c, _ = jax.lax.scan(inner, c, None, length=3)
            return c, None
        c, _ = jax.lax.scan(outer, x, None, length=5)
        return c

    text = compile_text(f, w, x)
    a = analyze_hlo(text)
    assert a.flops == pytest.approx(15 * 2 * 4 * 16 * 16, rel=0.05)


def test_hbm_bytes_reasonable():
    x = jnp.zeros((1024, 1024), jnp.float32)
    text = compile_text(lambda x: (x * 2 + 1).sum(), x)
    a = analyze_hlo(text)
    nbytes = 1024 * 1024 * 4
    # at least one read of x; at most a handful of round trips
    assert nbytes * 0.5 <= a.hbm_bytes <= nbytes * 6


def test_no_collectives_single_device():
    x = jnp.zeros((128,), jnp.float32)
    text = compile_text(lambda x: x.sum(), x)
    a = analyze_hlo(text)
    assert a.collective_bytes == 0
    assert a.n_collectives == 0
