"""Time-varying fault replay: FaultTimeline semantics, the three simulator
paths' bit-equality under timelines, the mid-flight re-planning controller,
and the replay scenario family's artifact contract."""
import math
import os

import pytest

from repro.core import lower_bounds as lb
from repro.core.model import BandwidthProfile, FaultTimeline
from repro.core.planner import make_plan, replay
from repro.core.simulator import simulate, simulate_reference
from repro.sweeps import build_artifact, run_scenario, validate_artifact
from repro.sweeps.scenarios import (ScenarioSpec, load_trace, smoke_grid,
                                    traces_dir)

P, N, K = 8, 1920, 12
ELL = 4.0


def _recovery_timeline(t_rec: float, ell: float = ELL) -> FaultTimeline:
    return FaultTimeline.make([(0.0, 0, ell), (t_rec, 0, 1.0)])


# ----------------------------------------------------------------------------
# FaultTimeline semantics
# ----------------------------------------------------------------------------

def test_timeline_is_deterministic_and_sorted():
    ev = [(50.0, 1, 2.0), (10.0, 0, 4.0), (50.0, 0, 1.0)]
    a = FaultTimeline.make(ev)
    b = FaultTimeline.make(list(reversed(ev)))
    assert a == b
    assert [e.t for e in a.events] == sorted(e.t for e in a.events)


def test_timeline_profile_at_folds_events():
    prof = BandwidthProfile.healthy(P)
    tl = _recovery_timeline(100.0)
    assert tl.profile_at(prof, 0.0).slowdown[0] == ELL
    assert tl.profile_at(prof, 99.9).slowdown[0] == ELL
    assert tl.profile_at(prof, 100.0).slowdown[0] == 1.0


def test_constant_timeline_has_no_breakpoints():
    prof = BandwidthProfile.single_straggler(P, ELL)
    tl = FaultTimeline.make([(0.0, 0, ELL)])
    breaks, _ = tl.after(0.0).segments(prof)
    assert list(breaks) == []


# ----------------------------------------------------------------------------
# simulator paths under timelines
# ----------------------------------------------------------------------------

def test_constant_timeline_reproduces_static_bit_exactly():
    """A timeline that never changes anything must leave the simulation on
    the static code path: IEEE-754-identical flow times, not just close."""
    prof = BandwidthProfile.single_straggler(P, ELL)
    plan = make_plan(prof, N, k=K)
    tl = FaultTimeline.make([(0.0, 0, ELL)]).after(0.0)
    static = simulate(plan.schedule)
    timed = simulate(plan.schedule, timeline=tl)
    assert timed.makespan == static.makespan
    assert timed.finish == static.finish
    assert timed.start == static.start


def test_vec_scalar_greedy_agree_under_timeline():
    """The segmented max-plus pass, the greedy event loop, and the reference
    event loop must produce bit-identical flow times under a mid-flight
    rate change (the vec_exact contract extended to timelines)."""
    prof = BandwidthProfile.single_straggler(P, 2.0)
    plan = make_plan(prof, N, k=K)
    assert plan.schedule.meta.get("vec_exact")
    scale = lb.t0_fault_free(P, N, 1)
    tl = FaultTimeline.make([(0.35 * scale, 0, 1.0),
                             (0.6 * scale, 3, 1.7)])
    fast = simulate(plan.schedule, timeline=tl)
    ref = simulate_reference(plan.schedule, timeline=tl)
    assert fast.makespan == ref.makespan
    assert fast.finish == ref.finish
    assert fast.start == ref.start


def test_recovery_at_zero_equals_healthy():
    """An event that 'recovers' a rank at t=0 is just a healthy profile."""
    prof = BandwidthProfile.single_straggler(P, ELL)
    tl = FaultTimeline.make([(0.0, 0, 1.0)])
    base = tl.profile_at(prof, 0.0)
    assert base.slowdown == BandwidthProfile.healthy(P).slowdown
    rr = replay(prof, N, tl, k=K)
    healthy_plan = make_plan(BandwidthProfile.healthy(P), N, k=K)
    assert rr.t_noreplan == simulate(healthy_plan.schedule).makespan
    assert rr.t_replan == rr.t_noreplan
    assert rr.replans == 0


# ----------------------------------------------------------------------------
# re-planning controller
# ----------------------------------------------------------------------------

def test_replan_never_worse_than_noreplan():
    prof = BandwidthProfile.single_straggler(P, ELL)
    scale = lb.t0_fault_free(P, N, 1)
    for frac in (0.15, 0.3, 0.5, 0.75):
        rr = replay(prof, N, _recovery_timeline(frac * scale), k=K)
        assert rr.t_replan <= rr.t_noreplan + 1e-9
        assert rr.t_replan >= rr.lower_bound * (1 - 1e-9)


def test_replan_strictly_wins_on_recovery():
    """Mid-flight recovery is where re-planning pays: the no-replan schedule
    keeps pacing itself for the departed straggler."""
    prof = BandwidthProfile.single_straggler(P, ELL)
    scale = lb.t0_fault_free(P, N, 1)
    rr = replay(prof, N, _recovery_timeline(0.35 * scale), k=K)
    assert rr.adopted_replan
    assert rr.t_replan < rr.t_noreplan
    assert rr.replans >= 1


def test_replay_checked_in_recovery_trace_strictly_wins():
    """Acceptance criterion: on the checked-in recovery trace, re-planning
    strictly beats riding the original schedule."""
    tr = load_trace(os.path.join(traces_dir(), "straggler_recovery.json"))
    events = tuple((float(t), int(r) % P, float(ell))
                   for t, r, ell in tr["events"])
    spec = ScenarioSpec(name="t", family="replay", p=P, n=N, k=K,
                        slowdown=(1.0,) * P,
                        simulate_ring=False, events=events)
    res = run_scenario(spec, measure_latency=False)
    assert res.t_optcc < res.t_noreplan


def test_load_trace_rejects_malformed(tmp_path):
    bad = tmp_path / "bad.json"
    bad.write_text('{"name": "x", "events": [[0.0, 0]]}')
    with pytest.raises(ValueError):
        load_trace(str(bad))


# ----------------------------------------------------------------------------
# scenario family + artifact contract
# ----------------------------------------------------------------------------

@pytest.fixture(scope="module")
def replay_results():
    specs = [s for s in smoke_grid(seed=0) if s.family == "replay"]
    assert specs, "smoke grid lost its replay family"
    return [run_scenario(s, measure_latency=False, telemetry=True)
            for s in specs[::3]]


def test_replay_rows_validate(replay_results):
    art = build_artifact(replay_results, profile="replay/3", seed=0,
                         deterministic=True, telemetry=True)
    assert validate_artifact(art) == []
    for rec in art["scenarios"]:
        assert rec["family"] == "replay"
        assert rec["events"]
        assert rec["t_optcc"] <= rec["t_noreplan"] * (1 + 1e-9)
        # stage attribution covers the whole no-replan run
        total = sum(rec["stage_breakdown"].values())
        assert math.isclose(total, rec["t_noreplan"], rel_tol=1e-6)


def test_replay_const_twin_is_bit_identical():
    """Acceptance criterion: the constant-timeline replay scenario equals
    its static-profile twin IEEE-754-exactly."""
    grid = smoke_grid(seed=0)
    const = [s for s in grid
             if s.family == "replay" and "const" in s.name]
    assert const
    for spec in const:
        ell = spec.events[0][2]
        twin = next(s for s in grid
                    if not s.events and s.p == spec.p and s.k == spec.k
                    and s.n == spec.n and s.stragglers == (0,)
                    and s.slowdown[0] == ell)
        r_replay = run_scenario(spec, measure_latency=False)
        r_static = run_scenario(twin, measure_latency=False)
        assert r_replay.t_noreplan == r_static.t_optcc
        assert r_replay.t_optcc == r_static.t_optcc
