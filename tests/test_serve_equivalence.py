"""Serving-path correctness: prefill + decode must reproduce the
teacher-forced forward pass (same logits trajectory), per family.

For each smoke arch: run forward() over a sequence; then prefill the
first half and decode the second half token-by-token; the decoded logits
must match the forward logits at the same positions.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.models import transformer, rwkv6, hymba
from repro.train.serve import generate, pad_cache_to

RNG = np.random.default_rng(5)


def forward_logits(cfg, params, tokens):
    if cfg.family in ("dense", "moe", "vlm"):
        h = transformer.forward(cfg, params, tokens)
        W = transformer.unembed_matrix(cfg, params)
    elif cfg.family == "rwkv6":
        h = rwkv6.forward(cfg, params, tokens)
        W = params["lm_head"]
    elif cfg.family == "hymba":
        h = hymba.forward(cfg, params, tokens)
        W = params["lm_head"]
    else:
        raise ValueError(cfg.family)
    return jnp.einsum("bsd,dv->bsv", h.astype(jnp.float32),
                      W.astype(jnp.float32))


@pytest.mark.parametrize("arch", [
    "qwen3-1.7b",          # dense + qk-norm + tied embeddings
    "gemma3-27b",          # local:global mixed caches
    "phi3.5-moe-42b-a6.6b",
    "rwkv6-7b",            # recurrent state
    "hymba-1.5b",          # window KV + ssm + conv states
])
def test_decode_matches_forward(arch):
    cfg = get_config(arch, smoke=True)
    if cfg.family == "moe":
        # Capacity-based token dropping depends on the sequence length the
        # router sees, so prefill(S/2) and forward(S) legitimately differ
        # under drops. Test the cache path itself with no-drop capacity.
        cfg = cfg.replace(capacity_factor=8.0)
    model = build_model(cfg)
    params = jax.jit(model.init)(jax.random.PRNGKey(1))
    B, S = 2, 24
    prompt_len = 12
    toks = jnp.asarray(RNG.integers(0, cfg.vocab_size, (B, S)))

    full = np.asarray(forward_logits(cfg, params, toks))

    logits, cache = jax.jit(model.prefill)(
        params, {"tokens": toks[:, :prompt_len]})
    np.testing.assert_allclose(
        np.asarray(logits), full[:, prompt_len - 1],
        rtol=2e-4, atol=2e-4,
        err_msg=f"{arch}: prefill logits != forward logits")

    cache = pad_cache_to(cache, S)
    step = jax.jit(model.decode_step)
    for pos in range(prompt_len, S):
        lg, cache = step(params, cache, toks[:, pos:pos + 1],
                         jnp.int32(pos))
        np.testing.assert_allclose(
            np.asarray(lg), full[:, pos], rtol=2e-4, atol=2e-4,
            err_msg=f"{arch}: decode logits diverge at pos {pos}")


def test_generate_greedy_consistency():
    cfg = get_config("qwen3-1.7b", smoke=True)
    model = build_model(cfg)
    params = jax.jit(model.init)(jax.random.PRNGKey(2))
    prompt = jnp.asarray(RNG.integers(0, cfg.vocab_size, (2, 8)))
    out1 = generate(model, params, prompt, 6)
    out2 = generate(model, params, prompt, 6)
    np.testing.assert_array_equal(out1, out2)
    assert out1.shape == (2, 6)
