"""Vectorized == scalar, bit-for-bit.

The vectorized generators (core.schedule_vec) and the vectorized simulator
fast paths (core.flowvec.simulate_arrays, simulator._simulate_greedy_fast)
are pure performance rewrites: for every supported profile they must produce
the *identical* flow graph and the *identical* IEEE-754 timing as the scalar
reference implementations (core.schedule / core.ring generators,
simulator.simulate_reference event loop). This file is the contract the
docstrings in simulator.py / flowvec.py / schedule_vec.py point at.

Two layers of checks:
  * graph equality: the columnar FlowArrays emitted by each vectorized
    generator equals FlowArrays.from_schedule(scalar generator output) -
    same endpoints, sizes, releases, priorities, NVLink flags, and the same
    dependency sets per flow;
  * timing equality: simulate() on the vectorized schedule returns the
    bit-identical makespan to simulate_reference() on the scalar schedule.

A deterministic seeded sweep always runs; a hypothesis property test widens
the search when hypothesis is installed (it is not a dependency).
"""
import random

import numpy as np
import pytest

from repro.core import BandwidthProfile, simulate
from repro.core.flowvec import FlowArrays
from repro.core.ring import ring_allreduce_schedule
from repro.core.schedule import optcc_schedule
from repro.core.schedule_vec import optcc_schedule_arrays, ring_arrays
from repro.core.simulator import simulate_reference


def _arrays_of(schedule) -> FlowArrays:
    if schedule.arrays is not None:
        return schedule.arrays
    return FlowArrays.from_schedule(schedule)


def _assert_same_graph(vec: FlowArrays, ref: FlowArrays) -> None:
    assert vec.nflows == ref.nflows
    np.testing.assert_array_equal(vec.src, ref.src)
    np.testing.assert_array_equal(vec.dst, ref.dst)
    np.testing.assert_array_equal(vec.size, ref.size)
    np.testing.assert_array_equal(vec.release, ref.release)
    np.testing.assert_array_equal(vec.nv, ref.nv)
    # NaN-aware priority comparison (NaN = unset, must match positionally).
    assert np.array_equal(vec.pri, ref.pri, equal_nan=True)
    # Dependencies are a *set* per flow (the simulator maxes over them), so
    # compare each flow's CSR slice order-insensitively.
    np.testing.assert_array_equal(vec.dep_indptr, ref.dep_indptr)
    for i in range(vec.nflows):
        a, b = vec.dep_indptr[i], vec.dep_indptr[i + 1]
        assert sorted(vec.dep_indices[a:b]) == sorted(ref.dep_indices[a:b]), \
            f"flow {i} deps differ"


def _profile_for(regime: str, p: int, g: int, ells) -> BandwidthProfile:
    if regime == "healthy":
        return BandwidthProfile.healthy(p, g=g)
    if regime in ("single", "ring-degraded"):
        return BandwidthProfile.single_straggler(p, ells, straggler=p // 3)
    if regime == "multi":
        return BandwidthProfile.multi_straggler(p, list(ells))
    return BandwidthProfile.single_straggler(p, ells, straggler=g and 1, g=g)


CASES = [
    # regime, p, g, ells, n, k
    ("healthy", 6, 1, None, 6 * 37, 1),
    ("healthy", 16, 1, None, 16 * 24 + 5, 1),
    ("ring-degraded", 8, 1, 1.5, 8 * 30, 1),     # ICCL baseline path
    ("ring-degraded", 12, 1, 8.0 / 7.0, 12 * 21 + 5, 1),
    ("single", 8, 1, 1.5, 7 * 4 * 12, 4),      # fill path (l < 2)
    ("single", 8, 1, 3.0, 7 * 4 * 12, 4),      # no-fill path (l >= 2)
    ("single", 16, 1, 8.0 / 7.0, 15 * 3 * 16 + 11, 3),   # ragged n
    ("single", 5, 1, 2.0, 4 * 2 * 10, 2),      # smallest slotted p
    ("multi", 12, 1, (1.5, 2.0), 10 * 4 * 9, 4),
    ("multi", 16, 1, (4.0 / 3.0, 8.0 / 7.0, 2.0), 13 * 2 * 8 + 3, 2),
    ("mgpu", 8, 2, 1.5, 2 * 4 * 3 * 10, 4),    # ordering A/B, q=4
    ("mgpu", 12, 2, 2.5, 2 * 2 * 5 * 8 + 7, 2),   # odd q=6... ragged n
    ("mgpu", 12, 4, 4.0 / 3.0, 4 * 3 * 2 * 12, 3),   # q=3 minimum
    ("mgpu", 32, 4, 2.0, 4 * 2 * 7 * 6 + 1, 2),
    ("mgpu", 24, 8, 3.0, 8 * 2 * 2 * 15, 2),
]


@pytest.mark.parametrize("regime,p,g,ells,n,k", CASES)
def test_generator_graphs_bit_equal(regime, p, g, ells, n, k):
    prof = _profile_for(regime, p, g, ells)
    if regime in ("healthy", "ring-degraded"):
        scalar = ring_allreduce_schedule(prof, n)
        vec = ring_arrays(prof, n)
    else:
        scalar = optcc_schedule(prof, n, k)
        vec = optcc_schedule_arrays(prof, n, k)
    _assert_same_graph(_arrays_of(vec), _arrays_of(scalar))


@pytest.mark.parametrize("regime,p,g,ells,n,k", CASES)
def test_simulated_times_bit_equal(regime, p, g, ells, n, k):
    """simulate() on the vectorized schedule == the scalar event loop on the
    scalar schedule, bit-for-bit (covers both the max-plus recurrence fast
    path for vec_exact schedules and the greedy columnar loop)."""
    prof = _profile_for(regime, p, g, ells)
    if regime in ("healthy", "ring-degraded"):
        scalar = ring_allreduce_schedule(prof, n)
        vec = ring_arrays(prof, n)
    else:
        scalar = optcc_schedule(prof, n, k)
        vec = optcc_schedule_arrays(prof, n, k)
    t_vec = simulate(vec).makespan
    t_ref = simulate_reference(scalar).makespan
    assert t_vec == t_ref          # bitwise, no tolerance


def test_greedy_fast_path_matches_reference_per_flow():
    """The columnar greedy loop agrees with the reference event loop on
    every flow's start/finish, not just the makespan."""
    prof = BandwidthProfile.multi_straggler(12, [1.5, 2.0])
    sched = optcc_schedule(prof, 10 * 4 * 9, 4)
    fast = simulate(sched)
    ref = simulate_reference(sched)
    assert fast.makespan == ref.makespan
    assert fast.start == ref.start
    assert fast.finish == ref.finish


def test_randomized_equivalence_seeded():
    """Deterministic randomized sweep (always runs, no hypothesis needed)."""
    rng = random.Random(20260809)
    for _ in range(20):
        regime = rng.choice(["healthy", "single", "multi", "mgpu"])
        if regime == "mgpu":
            g = rng.choice([2, 4])
            q = rng.randint(3, 6)
            p = g * q
            ells = rng.choice([1.25, 1.5, 2.0, 3.0])
        else:
            g = 1
            p = rng.randint(5, 20)
            q = None
            if regime == "single":
                ells = rng.choice([8.0 / 7.0, 1.5, 2.0, 4.0])
            elif regime == "multi":
                m = rng.randint(2, min(4, p - 2))
                ells = tuple(rng.choice([1.3, 1.5, 2.0, 2.5])
                             for _ in range(m))
            else:
                ells = None
        k = rng.randint(1, 6)
        units = p - (len(ells) if isinstance(ells, tuple) else 1)
        n = k * max(units, 1) * rng.randint(8, 24) + rng.randint(0, 13)
        prof = _profile_for(regime, p, g, ells)
        if regime == "healthy":
            scalar = ring_allreduce_schedule(prof, n)
            vec = ring_arrays(prof, n)
        else:
            scalar = optcc_schedule(prof, n, k)
            vec = optcc_schedule_arrays(prof, n, k)
        tag = (regime, p, g, ells, n, k)
        _assert_same_graph(_arrays_of(vec), _arrays_of(scalar))
        assert simulate(vec).makespan == \
            simulate_reference(scalar).makespan, tag


# ---------------------------------------------------------------------------
# Optional hypothesis widening (hypothesis is not a project dependency; the
# importorskip lives inside the test so only THIS test skips without it).
# ---------------------------------------------------------------------------
def test_property_vec_equals_scalar():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.settings(max_examples=25, deadline=None)
    @hyp.given(data=st.data())
    def run(data):
        regime = data.draw(st.sampled_from(["single", "multi", "mgpu"]))
        if regime == "mgpu":
            g = data.draw(st.sampled_from([2, 4, 8]))
            q = data.draw(st.integers(3, 8))
            p = g * q
            ells = data.draw(st.floats(1.05, 8.0))
        else:
            g = 1
            p = data.draw(st.integers(5, 32))
            if regime == "single":
                ells = data.draw(st.floats(1.05, 8.0))
            else:
                m = data.draw(st.integers(2, min(4, p - 2)))
                ells = tuple(data.draw(st.floats(1.05, 4.0))
                             for _ in range(m))
        k = data.draw(st.integers(1, 8))
        units = p - (len(ells) if isinstance(ells, tuple) else 1)
        n = k * units * data.draw(st.integers(4, 32)) + data.draw(
            st.integers(0, 17))
        prof = _profile_for(regime, p, g, ells)
        scalar = optcc_schedule(prof, n, k)
        vec = optcc_schedule_arrays(prof, n, k)
        _assert_same_graph(_arrays_of(vec), _arrays_of(scalar))
        assert simulate(vec).makespan == simulate_reference(scalar).makespan

    run()
