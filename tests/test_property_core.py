"""Property-based tests (hypothesis) for the system's core invariants.

Invariants:
  1. Any generated schedule computes a correct AllReduce for random inputs,
     sizes, straggler positions and slowdowns.
  2. Simulated time always dominates the information-theoretic lower bound.
  3. The planner's predicted time also dominates the bound.
  4. Integer splitting partitions ranges exactly.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need the hypothesis extra")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import BandwidthProfile, simulate, verify_allreduce
from repro.core import lower_bounds as lb
from repro.core.ring import split_points
from repro.core.schedule import optcc_schedule

SMALL = dict(max_examples=25, deadline=None)


@settings(**SMALL)
@given(p=st.integers(4, 12),
       ell=st.floats(1.01, 4.0),
       k=st.integers(1, 6),
       straggler=st.integers(0, 100),
       seed=st.integers(0, 2**31))
def test_single_straggler_always_correct(p, ell, k, straggler, seed):
    n = k * (p - 1) * 8
    prof = BandwidthProfile.single_straggler(p, ell, straggler=straggler % p)
    sched = optcc_schedule(prof, n, k)
    x = np.random.default_rng(seed).standard_normal((p, n))
    verify_allreduce(sched, x)


@settings(**SMALL)
@given(p=st.integers(6, 14),
       m=st.integers(2, 4),
       seed=st.integers(0, 2**31),
       data=st.data())
def test_multi_straggler_always_correct(p, m, seed, data):
    ells = data.draw(st.lists(st.floats(1.05, 3.5), min_size=m, max_size=m))
    k = 3
    n = k * (p - m) * 8
    prof = BandwidthProfile.multi_straggler(p, ells)
    sched = optcc_schedule(prof, n, k)
    x = np.random.default_rng(seed).standard_normal((p, n))
    verify_allreduce(sched, x)


@settings(**SMALL)
@given(g=st.integers(2, 4), q=st.integers(3, 6),
       ell=st.floats(1.05, 3.0), seed=st.integers(0, 2**31))
def test_multi_gpu_always_correct(g, q, ell, seed):
    k = 2
    p = g * q
    n = g * k * (q - 1) * 4
    prof = BandwidthProfile.single_straggler(p, ell, straggler=q - 1, g=g)
    sched = optcc_schedule(prof, n, k)
    x = np.random.default_rng(seed).standard_normal((p, n))
    verify_allreduce(sched, x)


@settings(**SMALL)
@given(p=st.integers(4, 10), ell=st.floats(1.01, 4.0), k=st.integers(2, 8))
def test_sim_time_dominates_lower_bound(p, ell, k):
    n = k * (p - 1) * 20
    prof = BandwidthProfile.single_straggler(p, ell)
    t = simulate(optcc_schedule(prof, n, k)).makespan
    assert t >= lb.lower_bound(p, n, [ell]) * (1 - 1e-9)


@settings(**SMALL)
@given(p=st.integers(4, 64), ell=st.floats(1.0, 8.0), k=st.integers(1, 64))
def test_closed_forms_dominate_bounds(p, ell, k):
    ells = [ell] if ell > 1.0 else []
    assert lb.optcc_time(p, 1.0, ells, k) >= \
        lb.lower_bound(p, 1.0, ells) * (1 - 1e-9)


@settings(max_examples=50, deadline=None)
@given(n=st.integers(0, 10_000), parts=st.integers(1, 64))
def test_split_points_partitions(n, parts):
    b = split_points(n, parts)
    assert b[0] == 0 and b[-1] == n
    assert (np.diff(b) >= 0).all()
    assert np.diff(b).sum() == n
