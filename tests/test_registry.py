"""Schedule registry + unified planner API (`make_plan(algo=...)`).

Contracts pinned here:
  1. Round-trip: every registered algorithm, on every profile it supports,
     generates a schedule that (a) computes a correct AllReduce at the data
     level, (b) simulates identically under the fast path and the scalar
     reference event loop, (c) finishes at or above the entry's own lower
     bound, and (d) carries the documented Schedule.meta key contract.
  2. `make_plan(algo="auto")` reproduces the historical OptCC-vs-ring
     planner choice (the PR-6 formula) on the static smoke grid, and the
     registry's ring/optcc time models equal the classic expressions.
  3. Deprecation shims: `force_ring=` and the old generator imports from
     `repro.core` keep working but warn.
"""
import warnings

import numpy as np
import pytest

from repro.core import (BandwidthProfile, make_plan, registry, simulate,
                        validate_schedule_meta, verify_allreduce)
from repro.core import lower_bounds as lb
from repro.core.planner import topology_of
from repro.core.registry import ScheduleAlgo
from repro.core.simulator import simulate_reference

RNG = np.random.default_rng(7)

# One profile pool covering every regime an entry may support: flat g=1,
# single/multi stragglers, composite and 2-D-factorable p, and multi-GPU
# servers (healthy + one degraded server).
PROFILES = [
    BandwidthProfile.healthy(8),
    BandwidthProfile.healthy(12),
    BandwidthProfile.single_straggler(8, 2.0, straggler=3),
    BandwidthProfile.single_straggler(16, 1.5, straggler=7),
    BandwidthProfile.multi_straggler(12, [1.5, 2.5]),
    BandwidthProfile.healthy(8, g=2),
    # straggler is a *server* index when g > 1
    BandwidthProfile.single_straggler(16, 2.0, straggler=1, g=4),
    BandwidthProfile.single_straggler(16, 4.0, straggler=4, g=2),
]


def _n_for(profile, k):
    g = profile.gpus_per_server
    units = max(profile.p // g - 1, 1)
    return g * k * units * 8


# ----------------------------------------------------------------------------
# registry API
# ----------------------------------------------------------------------------

def test_registry_names_and_lookup():
    assert set(registry.names()) >= {"ring", "optcc", "hierarchical",
                                     "dbtree", "torus2d"}
    with pytest.raises(ValueError, match="unknown schedule algo"):
        registry.get("nope")
    with pytest.raises(ValueError, match="already registered"):
        registry.register(ScheduleAlgo(
            name="ring", description="dup", generate=lambda *a: None,
            time_model=lambda *a: 0.0, lower_bound=lambda *a: 0.0))


def test_supported_filters_by_profile():
    flat = registry.supported(BandwidthProfile.healthy(8))
    assert "dbtree" in flat and "torus2d" in flat
    assert "hierarchical" not in flat           # needs g >= 2
    multi = registry.supported(BandwidthProfile.healthy(8, g=2))
    assert "hierarchical" in multi
    assert "dbtree" not in multi and "torus2d" not in multi
    prime = registry.supported(BandwidthProfile.healthy(7))
    assert "torus2d" not in prime               # no 2-D factorization
    assert {"ring", "optcc"} <= set(prime)


def test_auto_candidates_are_the_classic_pair():
    assert {a.name for a in registry.auto_candidates()} == {"ring", "optcc"}


# ----------------------------------------------------------------------------
# round-trip: every registered name x every supported profile
# ----------------------------------------------------------------------------

@pytest.mark.parametrize("name", registry.names())
def test_registry_round_trip(name):
    checked = 0
    for profile in PROFILES:
        if not registry.get(name).supports(profile):
            continue
        k = 4
        n = _n_for(profile, k)
        plan = make_plan(profile, n, k=k, algo=name)
        sched = plan.schedule
        validate_schedule_meta(sched)
        assert sched.meta["topology"] == topology_of(sched.meta["algo"])
        assert plan.topology == sched.meta["topology"]
        x = RNG.standard_normal((profile.p, n))
        verify_allreduce(sched, x)
        t_fast = simulate(sched).makespan
        t_ref = simulate_reference(sched).makespan
        assert t_fast == pytest.approx(t_ref, rel=1e-12), (name, profile.p)
        assert t_fast >= plan.lower_bound * (1 - 1e-9), (name, profile.p)
        assert plan.lower_bound == pytest.approx(
            registry.get(name).lower_bound(profile, n))
        checked += 1
    assert checked >= 2, f"profile pool never exercised {name}"


def test_unsupported_algo_raises():
    with pytest.raises(ValueError, match="does not support"):
        make_plan(BandwidthProfile.healthy(8), 640, k=4, algo="hierarchical")
    with pytest.raises(ValueError, match="does not support"):
        make_plan(BandwidthProfile.healthy(7), 630, k=3, algo="torus2d")
    with pytest.raises(ValueError, match="unknown schedule algo"):
        make_plan(BandwidthProfile.healthy(8), 640, k=4, algo="bogus")


# ----------------------------------------------------------------------------
# auto == the historical OptCC-vs-ring planner (the PR-6 pin)
# ----------------------------------------------------------------------------

def _classic_choice(profile, n, k):
    """The pre-registry planner formula, verbatim."""
    g = profile.gpus_per_server
    ells = [l for l in profile.slowdown if l > 1.0]
    if g > 1 and ells:
        ells = [max(ells)]
    ring_pred = max(profile.slowdown) * lb.t0_fault_free(profile.p, n, 1)
    optcc_pred = lb.optcc_time(profile.p, n, ells, k, g)
    return ring_pred <= optcc_pred, ring_pred, optcc_pred


def test_auto_matches_classic_choice_on_smoke_grid():
    from repro.sweeps.scenarios import smoke_grid
    static = [s for s in smoke_grid(seed=0)
              if not s.events and s.algo == "auto"][::5]
    assert len(static) >= 30
    for s in static:
        profile = s.profile()
        use_ring, ring_pred, optcc_pred = _classic_choice(profile, s.n, s.k)
        plan = make_plan(profile, s.n, k=s.k, fill_bubbles=s.fill_bubbles,
                         materialize="arrays")
        if use_ring:
            assert plan.algo == "ring", s.name
            assert plan.predicted_time == ring_pred, s.name
        else:
            assert plan.algo.startswith("optcc"), s.name
            assert plan.predicted_time == optcc_pred, s.name


def test_registry_time_models_mirror_classic_formulas():
    for profile in PROFILES:
        for n, k in ((_n_for(profile, 4), 4), (_n_for(profile, 16), 16)):
            _, ring_pred, optcc_pred = _classic_choice(profile, n, k)
            assert registry.get("ring").time_model(profile, n, k) == ring_pred
            assert registry.get("optcc").time_model(profile, n, k) == \
                optcc_pred


def test_explicit_ring_and_optcc_match_direct_generators():
    profile = BandwidthProfile.single_straggler(8, 1.5)
    n, k = 7 * 4 * 16, 4
    from repro.core.ring import ring_allreduce_schedule
    from repro.core.schedule import optcc_schedule
    ring_plan = make_plan(profile, n, k=k, algo="ring")
    assert simulate(ring_plan.schedule).makespan == \
        simulate(ring_allreduce_schedule(profile, n)).makespan
    optcc_plan = make_plan(profile, n, k=k, algo="optcc")
    assert simulate(optcc_plan.schedule).makespan == \
        simulate(optcc_schedule(profile, n, k)).makespan


# ----------------------------------------------------------------------------
# deprecation shims
# ----------------------------------------------------------------------------

def test_force_ring_shim_warns_and_works():
    # ell=4 at k=16 makes auto pick OptCC, so the shim values are
    # discernible (at shallow k the pipeline ramp keeps auto on the ring).
    profile = BandwidthProfile.single_straggler(8, 4.0)
    with pytest.warns(DeprecationWarning, match="force_ring"):
        plan = make_plan(profile, 560, k=16, force_ring=True)
    assert plan.algo == "ring"
    with pytest.warns(DeprecationWarning, match="force_ring"):
        plan = make_plan(profile, 560, k=16, force_ring=False)
    assert plan.algo.startswith("optcc")    # force_ring=False meant "auto"


def test_deprecated_core_imports_warn():
    import importlib

    import repro.core as core
    for name in ("optcc_schedule", "ring_allreduce_schedule",
                 "optcc_single_schedule"):
        with pytest.warns(DeprecationWarning, match=name):
            fn = getattr(core, name)
        assert callable(fn)
    # __all__ still advertises them, and the canonical modules stay quiet.
    assert "optcc_schedule" in core.__all__
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        importlib.import_module("repro.core.schedule").optcc_schedule
        importlib.import_module("repro.core.ring").ring_allreduce_schedule


# ----------------------------------------------------------------------------
# Schedule.meta contract + debug validator
# ----------------------------------------------------------------------------

def test_meta_validator_rejects_broken_meta():
    profile = BandwidthProfile.single_straggler(8, 1.5)
    sched = make_plan(profile, 560, k=4, algo="optcc").schedule
    good = dict(sched.meta)
    sched.meta.pop("topology")
    with pytest.raises(ValueError, match="topology"):
        validate_schedule_meta(sched)
    sched.meta.update(good)
    sched.meta["stage_ids"] = sched.meta["stage_ids"][:-1]
    with pytest.raises(ValueError, match="stage_ids"):
        validate_schedule_meta(sched)
    sched.meta.update(good)


def test_debug_mode_validates_meta(monkeypatch):
    profile = BandwidthProfile.single_straggler(8, 1.5)
    sched = make_plan(profile, 560, k=4, algo="optcc").schedule
    monkeypatch.setenv("REPRO_DEBUG", "1")
    simulate(sched)                           # valid meta passes
    del sched.meta["algo"]
    with pytest.raises(ValueError, match="algo"):
        simulate(sched)
