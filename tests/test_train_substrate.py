"""Training substrate: optimizer, schedules, data, checkpoint, train step."""
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.configs.base import ModelConfig
from repro.data import DataConfig, SyntheticLM
from repro.models import build_model
from repro.optim import (AdamWConfig, constant, cosine, global_norm,
                         init_state, update, warmup_stable_decay)
from repro.train import init_train_state, make_gspmd_train_step
from repro.checkpoint import latest_step, restore, save

TINY = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                   n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                   param_dtype="float32", compute_dtype="float32",
                   logits_chunk=32)


def test_adamw_reduces_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    cfg = AdamWConfig(weight_decay=0.0, clip_norm=0.0)
    state = init_state(params, cfg)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, _ = update(params, grads, state, 0.05, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.2


def test_adamw_moment_dtype():
    params = {"w": jnp.ones((4,), jnp.float32)}
    cfg = AdamWConfig(moment_dtype="bfloat16")
    state = init_state(params, cfg)
    assert state["mu"]["w"].dtype == jnp.bfloat16
    _, state, _ = update(params, {"w": jnp.ones(4)}, state, 1e-3, cfg)
    assert state["nu"]["w"].dtype == jnp.bfloat16


def test_clip_norm():
    g = {"a": jnp.full((10,), 100.0)}
    cfg = AdamWConfig(clip_norm=1.0)
    p = {"a": jnp.zeros(10)}
    s = init_state(p, cfg)
    p2, _, gnorm = update(p, g, s, 1.0, cfg)
    assert float(gnorm) == pytest.approx(float(global_norm(g)), rel=1e-5)
    assert np.isfinite(np.asarray(p2["a"])).all()


def test_schedules():
    wsd = warmup_stable_decay(1.0, warmup=10, stable=50, decay=40)
    assert float(wsd(0)) == 0.0
    assert float(wsd(10)) == pytest.approx(1.0)
    assert float(wsd(40)) == pytest.approx(1.0)
    assert float(wsd(100)) == pytest.approx(0.1, rel=1e-3)
    cos = cosine(1.0, warmup=5, total=100)
    assert float(cos(5)) == pytest.approx(1.0)
    assert float(cos(100)) == pytest.approx(0.1, rel=1e-3)


def test_data_determinism_and_sharding():
    cfg = DataConfig(vocab_size=128, seq_len=16, global_batch=8, seed=3)
    full = SyntheticLM(cfg)
    b1 = full.batch(7)
    b2 = full.batch(7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # shards partition the global batch deterministically
    shards = [SyntheticLM(cfg, shard_id=i, num_shards=4) for i in range(4)]
    got = np.concatenate([s.batch(7)["tokens"] for s in shards])
    np.testing.assert_array_equal(got, b1["tokens"])
    # labels are next tokens
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6.0).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.bfloat16)}}
    save(tmp_path, 5, tree, meta={"note": "x"})
    save(tmp_path, 9, jax.tree.map(lambda x: x * 2, tree))
    assert latest_step(tmp_path) == 9
    out, meta = restore(tmp_path, tree)
    np.testing.assert_allclose(np.asarray(out["a"], np.float32),
                               np.arange(6.0).reshape(2, 3) * 2)
    assert meta["step"] == 9
    out5, meta5 = restore(tmp_path, tree, step=5)
    assert meta5["note"] == "x"


def test_checkpoint_atomicity(tmp_path):
    tree = {"a": jnp.zeros(3)}
    save(tmp_path, 1, tree)
    # a stale tmp dir from a crashed save must not count as a checkpoint
    (tmp_path / ".tmp_step_2").mkdir()
    assert latest_step(tmp_path) == 1


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    save(tmp_path, 1, {"a": jnp.zeros((2, 2))})
    with pytest.raises(ValueError, match="shape mismatch"):
        restore(tmp_path, {"a": jnp.zeros((3, 3))})


def test_train_step_learns_and_resumes(tmp_path):
    model = build_model(TINY)
    opt = AdamWConfig(weight_decay=0.01)
    state = init_train_state(model, opt)
    mesh = Mesh(np.array(jax.devices()), ("data",))
    step = jax.jit(make_gspmd_train_step(model, mesh, opt, constant(1e-2)))
    data = SyntheticLM(DataConfig(vocab_size=256, seq_len=64,
                                  global_batch=8))
    losses = []
    for i in range(60):
        b = data.batch(i)
        state, m = step(state, jax.tree.map(jnp.asarray, b))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3, (losses[0], losses[-1])
    assert all(np.isfinite(losses))

    # checkpoint -> restore -> identical continuation (restart determinism)
    save(tmp_path, 60, state)
    state2, _ = restore(tmp_path, state)
    b = data.batch(60)
    s_a, m_a = step(state, jax.tree.map(jnp.asarray, b))
    s_b, m_b = step(state2, jax.tree.map(jnp.asarray, b))
    assert float(m_a["loss"]) == pytest.approx(float(m_b["loss"]), abs=1e-6)


def test_microbatched_step_matches_plain():
    model = build_model(TINY)
    opt = AdamWConfig(weight_decay=0.0, clip_norm=0.0)
    mesh = Mesh(np.array(jax.devices()), ("data",))
    data = SyntheticLM(DataConfig(vocab_size=256, seq_len=32,
                                  global_batch=8))
    b = jax.tree.map(jnp.asarray, data.batch(0))
    s1 = init_train_state(model, opt, seed=1)
    s2 = init_train_state(model, opt, seed=1)
    plain = jax.jit(make_gspmd_train_step(model, mesh, opt, constant(1e-3)))
    micro = jax.jit(make_gspmd_train_step(model, mesh, opt, constant(1e-3),
                                          num_microbatches=4))
    s1, m1 = plain(s1, b)
    s2, m2 = micro(s2, b)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-5)
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                     s1.params, s2.params)
    assert max(jax.tree.leaves(d)) < 2e-5
