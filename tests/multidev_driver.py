"""Driver executed in a subprocess with 8 forced host devices.

Must set XLA_FLAGS before importing jax - which is why these checks cannot
run inside the main pytest process (smoke tests there must see 1 device).
Prints 'ALL-OK' on success; any assertion failure raises.
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", ""))

import functools  # noqa: E402
import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import Mesh, PartitionSpec as P  # noqa: E402
from jax import shard_map  # noqa: E402

from repro.comms import (compressed_psum, optcc_allreduce,  # noqa: E402
                         optcc_allreduce_tree, ring_all_gather,
                         ring_allreduce, ring_reduce_scatter)


def main():
    assert jax.device_count() == 8, jax.device_count()
    mesh = Mesh(np.array(jax.devices()), ("dp",))
    p = 8
    rng = np.random.default_rng(0)
    n = 1000
    x = rng.standard_normal((p, n)).astype(np.float32)
    expected = x.sum(0)

    def run(fn):
        sharded = shard_map(fn, mesh=mesh, in_specs=P("dp"),
                            out_specs=P("dp"))
        return jax.jit(sharded)(x)

    # --- ring allreduce == psum ---------------------------------------
    def f_ring(xs):
        return ring_allreduce(xs[0], "dp")[None]
    out = run(f_ring)
    for r in range(p):
        np.testing.assert_allclose(out[r], expected, rtol=1e-5, atol=1e-5)
    print("ring_allreduce OK")

    # --- ring RS + AG halves ------------------------------------------
    def f_rs(xs):
        chunk = ring_reduce_scatter(xs[0], "dp")
        return ring_all_gather(chunk, "dp")[None]
    out = run(f_rs)
    for r in range(p):
        np.testing.assert_allclose(out[r], expected, rtol=1e-5, atol=1e-5)
    print("ring RS/AG OK")

    # --- optcc_allreduce for every straggler position ------------------
    for straggler in (0, 3, 7):
        def f_optcc(xs):
            return optcc_allreduce(xs[0], "dp", straggler, p)[None]
        out = run(f_optcc)
        for r in range(p):
            np.testing.assert_allclose(out[r], expected, rtol=1e-5,
                                       atol=1e-5)
    print("optcc_allreduce OK")

    # --- optcc on a pytree (gradient-like) ------------------------------
    tree = {"w": x[:, :600].reshape(p, 20, 30),
            "b": x[:, 600:607]}
    def f_tree(t):
        sub = jax.tree.map(lambda a: a[0], t)
        out = optcc_allreduce_tree(sub, "dp", 2, p)
        return jax.tree.map(lambda a: a[None], out)
    sharded = shard_map(f_tree, mesh=mesh,
                        in_specs=(P("dp"),), out_specs=P("dp"))
    out = jax.jit(sharded)(tree)
    np.testing.assert_allclose(out["w"][0], x[:, :600].sum(0).reshape(20, 30),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(out["b"][3], x[:, 600:607].sum(0),
                               rtol=1e-5, atol=1e-5)
    print("optcc_allreduce_tree OK")

    # --- straggler link volume: count ppermute bytes touching straggler --
    # Structural check on the jaxpr: the optcc program contains exactly
    # 2 ppermutes whose permutation includes the straggler (in + out).
    def f_s(xs):
        return optcc_allreduce(xs[0], "dp", 0, p)[None]
    jaxpr = jax.make_jaxpr(
        shard_map(f_s, mesh=mesh, in_specs=P("dp"), out_specs=P("dp")))(x)
    text = str(jaxpr)
    n_perm_with_straggler = text.count("(0, 1)") + text.count("(1, 0)")
    assert n_perm_with_straggler >= 2, text[:500]
    print("straggler-volume structure OK")

    # --- compressed psum with error feedback ----------------------------
    def f_comp(xs):
        out, err = compressed_psum(xs[0], "dp")
        return out[None], err[None]
    sharded = shard_map(f_comp, mesh=mesh, in_specs=P("dp"),
                        out_specs=(P("dp"), P("dp")))
    out, err = jax.jit(sharded)(x)
    rel = np.abs(out[0] - expected) / (np.abs(expected) + 1e-3)
    assert rel.mean() < 0.05, rel.mean()   # int8 quantization error bound
    # error feedback: next-step correction reduces bias
    assert np.abs(err).sum() > 0
    print("compressed_psum OK")

    failover_equivalence()

    print("ALL-OK")


def failover_equivalence():
    """Degraded-mode (OptCC) training == healthy (psum) training, bitwise
    up to fp tolerance: 3 steps each on 8 DP shards."""
    from repro.configs.base import ModelConfig
    from repro.models import build_model
    from repro.optim import AdamWConfig
    from repro.optim.schedules import constant
    from repro.train import init_train_state, make_dp_failover_step
    from repro.comms.fault import FaultState
    from repro.data import DataConfig, SyntheticLM

    cfg = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=32,
                      n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=128,
                      param_dtype="float32", compute_dtype="float32",
                      logits_chunk=16)
    model = build_model(cfg)
    opt = AdamWConfig(weight_decay=0.0)
    mesh = Mesh(np.array(jax.devices()), ("data",))
    data = SyntheticLM(DataConfig(vocab_size=128, seq_len=32,
                                  global_batch=8))
    healthy = make_dp_failover_step(model, mesh, opt, constant(1e-3),
                                    FaultState(axis_size=8))
    degraded = make_dp_failover_step(model, mesh, opt, constant(1e-3),
                                     FaultState(axis_size=8, straggler=3,
                                                ell=1.75))
    s_h = init_train_state(model, opt, seed=7)
    s_d = init_train_state(model, opt, seed=7)
    for i in range(3):
        b = jax.tree.map(jnp.asarray, data.batch(i))
        s_h, m_h = healthy(s_h, b)
        s_d, m_d = degraded(s_d, b)
        assert abs(float(m_h["loss"]) - float(m_d["loss"])) < 1e-5
    diffs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                         s_h.params, s_d.params)
    assert max(jax.tree.leaves(diffs)) < 1e-5, diffs
    print("failover-equivalence OK")


if __name__ == "__main__":
    main()
