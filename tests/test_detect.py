"""Imperfect fault detection: the probe-based detector model, controller
policies (immediate / debounce / backoff), mis-plan-tolerant execution in
`planner.replay`, and the detection scenario family's artifact contract.

The two acceptance pins:
  * a perfect detector (zero latency/noise, no FP/FN, immediate policy) is
    bit-identical to the PR-8 oracle controller on every checked-in
    ci/traces file;
  * the default imperfect detector on the nic_flap trace re-plans strictly
    less under debounce than under immediate, at an equal-or-better
    makespan.
"""
import math
import os

import pytest

from repro.core import lower_bounds as lb
from repro.core.model import BandwidthProfile, FaultTimeline
from repro.core.planner import make_plan, replay
from repro.detect import (MAX_CREDIBLE_ELL, POLICIES, ControllerConfig,
                          DetectorConfig, apply_policy, debounce_timeline,
                          estimate_timeline, estimate_usable)
from repro.sweeps import build_artifact, run_scenario, validate_artifact
from repro.sweeps.scenarios import load_trace, smoke_grid, traces_dir

P, N, K = 8, 1920, 12
TRACES = ("nic_flap.json", "straggler_recovery.json", "reroute_cascade.json")


def _trace_timeline(name: str) -> FaultTimeline:
    tr = load_trace(os.path.join(traces_dir(), name))
    scale = lb.t0_fault_free(P, N, 1)
    return FaultTimeline.make([(t * scale, int(r) % P, ell)
                               for t, r, ell in tr["events"]])


def _default_detector(seed: int = 0) -> DetectorConfig:
    return DetectorConfig.default(scale=lb.t0_fault_free(P, N, 1), seed=seed)


# ----------------------------------------------------------------------------
# acceptance pins
# ----------------------------------------------------------------------------

@pytest.mark.parametrize("trace", TRACES)
@pytest.mark.parametrize("policy", POLICIES)
def test_perfect_detector_bit_identical_to_oracle(trace, policy):
    """Zero-latency, zero-noise, FP=FN=0 detection must leave replay on the
    PR-8 path IEEE-754-exactly, under every policy (their windows/floors all
    collapse with a perfect continuous detector)."""
    prof = BandwidthProfile.healthy(P)
    tl = _trace_timeline(trace)
    oracle = replay(prof, N, tl, k=K)
    seen = replay(prof, N, tl, k=K, detector=DetectorConfig.perfect(),
                  controller=ControllerConfig(policy=policy))
    assert seen.t_chain == oracle.t_chain
    assert seen.t_noreplan == oracle.t_noreplan
    assert seen.t_replan == oracle.t_replan
    assert seen.replans == oracle.replans
    assert seen.false_replans == 0
    assert seen.detect_lag_max in (None, 0.0)


def test_debounce_beats_immediate_on_nic_flap():
    """Acceptance criterion: on the flapping-NIC trace the default imperfect
    detector re-plans strictly fewer times under debounce than under
    immediate, at an equal-or-better makespan."""
    prof = BandwidthProfile.healthy(P)
    tl = _trace_timeline("nic_flap.json")
    det = _default_detector()
    imm = replay(prof, N, tl, k=K, detector=det,
                 controller=ControllerConfig(policy="immediate"))
    deb = replay(prof, N, tl, k=K, detector=det,
                 controller=ControllerConfig(policy="debounce"))
    assert deb.replans < imm.replans
    assert deb.t_replan <= imm.t_replan * (1 + 1e-12)
    assert deb.suppressed >= 1


def test_backoff_bounds_replan_churn_on_nic_flap():
    prof = BandwidthProfile.healthy(P)
    tl = _trace_timeline("nic_flap.json")
    det = _default_detector()
    imm = replay(prof, N, tl, k=K, detector=det,
                 controller=ControllerConfig(policy="immediate"))
    bo = replay(prof, N, tl, k=K, detector=det,
                controller=ControllerConfig(policy="backoff"))
    assert bo.replans <= imm.replans
    # The adopted makespan never regresses past no-replan by construction.
    assert bo.t_replan <= bo.t_noreplan * (1 + 1e-12)


# ----------------------------------------------------------------------------
# detector model
# ----------------------------------------------------------------------------

def test_perfect_estimate_reproduces_truth_verbatim():
    prof = BandwidthProfile.healthy(P)
    tl = _trace_timeline("reroute_cascade.json")
    d = estimate_timeline(prof, tl, horizon=1e9,
                          config=DetectorConfig.perfect())
    # The estimate omits t<=0 events (the launch profile is known exactly),
    # so compare against the t=0-folded base, as replay does.
    prof0 = tl.profile_at(prof, 0.0)
    assert d.timeline.changes(prof0) == tl.changes(prof)
    assert d.missed == 0 and d.false_events == 0
    assert set(d.lags) <= {0.0}


def test_continuous_latency_shifts_every_change():
    prof = BandwidthProfile.healthy(P)
    tl = FaultTimeline.make([(100.0, 2, 3.0), (400.0, 2, 1.0)])
    d = estimate_timeline(prof, tl, horizon=1e4,
                          config=DetectorConfig(latency=25.0))
    assert [ev.t for ev in d.timeline.events] == [125.0, 425.0]
    assert d.lags == (25.0, 25.0)
    assert d.missed == 0


def test_probed_detection_lags_by_probe_cadence():
    prof = BandwidthProfile.healthy(P)
    tl = FaultTimeline.make([(105.0, 1, 2.0)])
    d = estimate_timeline(prof, tl, horizon=1000.0,
                          config=DetectorConfig(probe_interval=50.0))
    # First probe at/after the change is t=150.
    assert [ev.t for ev in d.timeline.events] == [150.0]
    assert d.lags == (45.0,)
    assert d.probes == 20


def test_quantization_snaps_reported_ell():
    prof = BandwidthProfile.healthy(P)
    tl = FaultTimeline.make([(10.0, 0, 1.9)])
    d = estimate_timeline(prof, tl, horizon=100.0,
                          config=DetectorConfig(probe_interval=20.0,
                                                quant=0.25))
    (ev,) = d.timeline.events
    assert ev.ell == 2.0                        # 1.9 -> nearest 1 + m/4
    # Recoveries always pass through exactly.
    tl2 = FaultTimeline.make([(10.0, 0, 1.9), (50.0, 0, 1.0)])
    d2 = estimate_timeline(prof, tl2, horizon=100.0,
                           config=DetectorConfig(probe_interval=20.0,
                                                 noise=0.3, quant=0.25,
                                                 seed=3))
    assert d2.timeline.events[-1].ell == 1.0


def test_estimate_is_deterministic_per_seed():
    prof = BandwidthProfile.healthy(P)
    tl = _trace_timeline("nic_flap.json")
    cfg = _default_detector(seed=5)
    a = estimate_timeline(prof, tl, horizon=1e7, config=cfg)
    b = estimate_timeline(prof, tl, horizon=1e7, config=cfg)
    assert a.timeline == b.timeline and a.lags == b.lags
    c = estimate_timeline(prof, tl, horizon=1e7,
                          config=_default_detector(seed=6))
    assert c.timeline != a.timeline or c.lags != a.lags


def test_false_positives_blip_and_clear():
    prof = BandwidthProfile.healthy(P)
    tl = FaultTimeline.make([])
    cfg = DetectorConfig(probe_interval=10.0, fp_rate=0.5, fp_ell=3.0,
                         seed=1)
    d = estimate_timeline(prof, tl, horizon=1000.0, config=cfg)
    assert d.false_events > 0
    changes = d.timeline.changes(prof)
    for r, chs in changes.items():
        # Effective changes alternate blip/clear (back-to-back blips on the
        # same rank merge) and always land on probe ticks.
        for i, (t, v) in enumerate(chs):
            assert v == (3.0 if i % 2 == 0 else 1.0)
            assert math.isclose(t % 10.0, 0.0, abs_tol=1e-9)


def test_false_negatives_add_geometric_lag():
    prof = BandwidthProfile.healthy(P)
    tl = FaultTimeline.make([(5.0, 0, 4.0)])
    base = estimate_timeline(prof, tl, horizon=1e4,
                             config=DetectorConfig(probe_interval=10.0))
    fn = estimate_timeline(prof, tl, horizon=1e4,
                           config=DetectorConfig(probe_interval=10.0,
                                                 fn_rate=0.9, seed=2))
    assert fn.lags[0] >= base.lags[0]
    assert fn.lags[0] % 10.0 == base.lags[0] % 10.0   # whole probes of delay


def test_detector_config_validation():
    with pytest.raises(ValueError):
        DetectorConfig(probe_interval=-1.0)
    with pytest.raises(ValueError):
        DetectorConfig(fp_rate=1.0, probe_interval=1.0)
    with pytest.raises(ValueError):
        DetectorConfig(fn_rate=0.1)       # FN needs discrete probes
    with pytest.raises(ValueError):
        DetectorConfig(fp_ell=0.5, probe_interval=1.0)
    assert DetectorConfig.perfect().is_perfect
    assert not _default_detector().is_perfect


# ----------------------------------------------------------------------------
# controller policies
# ----------------------------------------------------------------------------

def test_debounce_suppresses_subcadence_flap():
    prof = BandwidthProfile.healthy(P)
    # Flap up and back inside one debounce window: the degradation is
    # suppressed outright; the settle-back confirms but is a no-op trigger
    # (it re-states the value the estimate already carries), so the flap
    # produces zero effective re-plan triggers.
    tl = FaultTimeline.make([(100.0, 0, 2.0), (110.0, 0, 1.0),
                             (500.0, 1, 3.0)])
    confirmed, suppressed = debounce_timeline(tl, prof, probe_interval=10.0,
                                              k=3)
    assert suppressed == 1
    assert sorted(confirmed.changes(prof)) == [1]   # rank 0: no effective one
    ev = confirmed.changes(prof)[1]
    assert ev == [(520.0, 3.0)]


def test_debounce_k1_and_continuous_are_identity():
    prof = BandwidthProfile.healthy(P)
    tl = FaultTimeline.make([(100.0, 0, 2.0)])
    assert debounce_timeline(tl, prof, 10.0, 1) == (tl, 0)
    assert debounce_timeline(tl, prof, 0.0, 5) == (tl, 0)


def test_pure_fp_trace_never_confirms_under_debounce():
    """A detector seeing only one-probe FP blips must not trigger a single
    re-plan once debounced (the failover demo exits non-zero on this)."""
    prof = BandwidthProfile.healthy(P)
    det = DetectorConfig(probe_interval=50.0, fp_rate=0.3, seed=11)
    rr = replay(prof, N, FaultTimeline.make([]), k=K, detector=det,
                controller=ControllerConfig(policy="debounce"))
    assert rr.replans == 0
    assert rr.false_replans == 0
    assert rr.suppressed > 0
    assert rr.t_replan == rr.t_noreplan


def test_backoff_spacing_doubles():
    cfg = ControllerConfig(policy="backoff", backoff_base=8.0)
    assert [cfg.backoff_spacing(1.0, i) for i in (1, 2, 3)] == [8.0, 16.0,
                                                                32.0]
    auto = ControllerConfig(policy="backoff")
    assert auto.backoff_spacing(5.0, 1) == 20.0   # 4 probe intervals


def test_controller_config_validation():
    with pytest.raises(ValueError):
        ControllerConfig(policy="yolo")
    with pytest.raises(ValueError):
        ControllerConfig(debounce_probes=0)
    with pytest.raises(ValueError):
        replay(BandwidthProfile.healthy(P), N, FaultTimeline.make([]), k=K,
               controller=ControllerConfig())   # controller needs detector


def test_unusable_estimate_forces_ring_fallback():
    assert not estimate_usable(
        BandwidthProfile.single_straggler(P, MAX_CREDIBLE_ELL * 2))
    assert not estimate_usable(
        BandwidthProfile(P, tuple([4.0] * (P - 1) + [1.0])))
    assert estimate_usable(BandwidthProfile.single_straggler(P, 4.0))
    plan = make_plan(BandwidthProfile.single_straggler(P, 4.0), N, k=K,
                     algo="ring")
    assert plan.algo == "ring"


def test_apply_policy_immediate_passes_through():
    prof = BandwidthProfile.healthy(P)
    tl = _trace_timeline("nic_flap.json")
    d = estimate_timeline(prof, tl, horizon=1e7, config=_default_detector())
    out, suppressed = apply_policy(d, prof, ControllerConfig())
    assert out == d.timeline and suppressed == 0


# ----------------------------------------------------------------------------
# mis-plan execution
# ----------------------------------------------------------------------------

def test_misplan_executes_against_truth():
    """A noisy estimate changes the plan, but simulation runs at true
    rates: the detected makespan must stay within [oracle, no-replan]."""
    prof = BandwidthProfile.healthy(P)
    tl = _trace_timeline("straggler_recovery.json")
    oracle = replay(prof, N, tl, k=K)
    det = replay(prof, N, tl, k=K,
                 detector=DetectorConfig(probe_interval=0.0, noise=0.4,
                                         seed=4),
                 controller=ControllerConfig())
    assert det.t_replan >= oracle.t_replan * (1 - 1e-12)
    assert det.t_replan <= det.t_noreplan * (1 + 1e-12)
    assert det.t_noreplan == oracle.t_noreplan   # truth-driven either way


def test_detection_results_attach_to_replay():
    prof = BandwidthProfile.healthy(P)
    tl = _trace_timeline("nic_flap.json")
    rr = replay(prof, N, tl, k=K, detector=_default_detector(),
                controller=ControllerConfig(policy="debounce"))
    assert rr.policy == "debounce"
    assert rr.detection is not None and rr.detection.probes > 0
    assert rr.detect_lag_mean is None or rr.detect_lag_mean >= 0.0
    oracle = replay(prof, N, tl, k=K)
    assert oracle.policy == "oracle" and oracle.detection is None


# ----------------------------------------------------------------------------
# FailureInjector -> FaultTimeline bridge
# ----------------------------------------------------------------------------

def test_injector_to_timeline_diffs_states():
    from repro.comms.fault import FailureInjector, FaultState
    inj = FailureInjector.nic_loss(P, step=100, straggler=3, ell=2.5,
                                   repair_step=200)
    tl = inj.to_timeline(t_per_step=2.0)
    assert [(e.t, e.rank, e.ell) for e in tl.events] == \
        [(200.0, 3, 2.5), (400.0, 3, 1.0)]
    # Only ranks whose slowdown changes emit events; a step that re-states
    # the same whole-cluster state emits nothing.
    inj2 = FailureInjector(P, {10: FaultState(P, 0, 2.0),
                               20: FaultState(P, 0, 2.0),
                               30: FaultState(P, 1, 3.0)})
    tl2 = inj2.to_timeline(t_per_step=1.0)
    assert [(e.t, e.rank, e.ell) for e in tl2.events] == \
        [(10.0, 0, 2.0), (30.0, 0, 1.0), (30.0, 1, 3.0)]
    with pytest.raises(ValueError):
        inj.to_timeline(t_per_step=0.0)


def test_injector_timeline_drives_replay():
    from repro.comms.fault import FailureInjector
    inj = FailureInjector.nic_loss(P, step=0, straggler=0, ell=4.0,
                                   repair_step=5)
    scale = lb.t0_fault_free(P, N, 1)
    tl = inj.to_timeline(t_per_step=0.1 * scale)
    rr = replay(BandwidthProfile.healthy(P), N, tl, k=K)
    assert rr.replans >= 1
    assert rr.t_replan <= rr.t_noreplan


# ----------------------------------------------------------------------------
# scenario family + artifact contract
# ----------------------------------------------------------------------------

@pytest.fixture(scope="module")
def detection_results():
    specs = [s for s in smoke_grid(seed=0) if s.family == "detection"]
    assert specs, "smoke grid lost its detection family"
    assert {dict(s.detection)["policy"] for s in specs} == set(POLICIES)
    return [run_scenario(s, measure_latency=False) for s in specs[::7]]


def test_detection_rows_validate(detection_results):
    art = build_artifact(detection_results, profile="detect/7", seed=0,
                         deterministic=True)
    assert validate_artifact(art) == []
    assert set(art["summary"]["by_policy"]) <= set(POLICIES)
    for rec in art["scenarios"]:
        assert rec["family"] == "detection"
        assert rec["policy"] in POLICIES
        assert rec["t_optcc"] <= rec["t_noreplan"] * (1 + 1e-9)
        assert rec["overhead_vs_oracle"] >= 1.0 - 1e-9 or \
            rec["t_optcc"] <= rec["t_oracle"]
        assert rec["detection"]["probe_interval"] > 0


def test_detection_summary_has_oracle_percentiles(detection_results):
    art = build_artifact(detection_results, profile="detect/7", seed=0,
                         deterministic=True)
    det = art["summary"]["by_family"]["detection"]
    for key in ("overhead_vs_oracle_p50", "overhead_vs_oracle_p99",
                "overhead_vs_oracle_max", "false_replans_total"):
        assert key in det
    for st in art["summary"]["by_policy"].values():
        assert st["count"] > 0


def test_policy_on_non_detection_row_rejected(detection_results):
    art = build_artifact(detection_results, profile="detect/7", seed=0,
                         deterministic=True)
    art["scenarios"][0]["family"] = "replay"
    errs = validate_artifact(art)
    assert any("policy on a non-detection" in e for e in errs)
